//! # nodal — Adaptive Checkpoint Adjoint gradient estimation for Neural ODEs
//!
//! Rust + JAX + Pallas reproduction of *"Adaptive Checkpoint Adjoint Method for
//! Gradient Estimation in Neural ODE"* (Zhuang et al., ICML 2020).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — adaptive explicit Runge–Kutta solving with PI
//!   step-size control ([`ode`]), the paper's trajectory-checkpoint data
//!   structure and the three gradient-estimation strategies — **naive**,
//!   **adjoint**, **ACA** ([`grad`]) — plus training ([`train`]), data
//!   generation ([`data`]), metrics ([`metrics`]) and the experiment
//!   coordinator ([`coordinator`]). Independent solves batch through the
//!   **batched engine** ([`ode::integrate_batch`] /
//!   [`ode::integrate_batch_spans`] + [`grad::aca_backward_batch`]): flat
//!   `[B × D]` state buffers, a shared checkpoint arena, per-sample
//!   adaptive step control **and per-sample integration spans** (each
//!   sample stops at its own `t1`) with per-sample exact
//!   `nfe`/`avg_m`/memory meters, and one [`ode::OdeFunc::eval_batch`]
//!   stage sweep over all live samples — the hook a batched backend
//!   (single HLO dispatch, SIMD) overrides. The
//!   backward pass is symmetric: the **shared-stage reverse sweep**
//!   ([`grad::step_vjp_batch`]) replays the recorded discretization for all
//!   samples sharing a reverse round with one `eval_batch` stage recompute
//!   and one [`ode::OdeFunc::vjp_batch`] pullback per stage, retiring each
//!   sample as its reverse index underflows — per-sample gradients and
//!   meters stay bit-identical to the scalar path (`cargo bench --bench
//!   grad_backward` measures the speedup over per-sample replay).
//!   Trajectory state storage is owned by the **checkpoint store**
//!   ([`ckpt`]): a [`ckpt::CkptPolicy`] per solve — `Dense` (default,
//!   bit-for-bit the historical behavior), `EveryK`, or `Budgeted` (a byte
//!   budget held **mid-solve** by live thinning) — with dropped states
//!   regenerated **bit-exactly** by segment replay from the nearest anchor
//!   (the recorded `hs` are exact, so replay is the identical float
//!   computation; `nfe_replay` meters the recompute cost, and `cargo bench
//!   --bench ckpt_memory` tracks peak bytes vs replay overhead). On top of
//!   the batched engine sits the **solve server** ([`serve`]): a dynamic
//!   micro-batching layer that coalesces concurrent solve requests —
//!   including requests with **entirely different integration spans** (the
//!   batch key pins dynamics/solver/tolerance/direction; both `t0` and
//!   `t1` are free per request) — under a `max_batch_size`/
//!   `max_queue_delay` flush policy, with two-dimensional admission
//!   control (request count AND projected checkpoint bytes against a
//!   worker memory budget), QoS scheduling (priority lanes + per-dynamics
//!   deficit quotas), p50/p95/p99 latency metrics (aggregate and
//!   per-tenant), an HTTP/1.1 front door ([`serve::HttpServer`]), and
//!   `NODAL_SERVE_*` / `NODAL_HTTP_*` / `NODAL_CKPT_BUDGET_BYTES` tuning
//!   knobs.
//! * **L2 (JAX, `python/compile/model.py`)** — model dynamics `f(z, t, θ)`,
//!   encoders/decoders/loss heads, AOT-lowered to HLO text.
//! * **L1 (Pallas, `python/compile/kernels/`)** — fused hot-path kernels
//!   called from the L2 graphs.
//!
//! At runtime the coordinator executes the AOT artifacts through PJRT
//! ([`runtime`]); Python never runs on the training path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use nodal::ode::{analytic::VanDerPol, integrate, IntegrateOpts, tableau};
//!
//! let f = VanDerPol::new(0.15);
//! let traj = integrate(&f, 0.0, 25.0, &[2.0, 0.0], tableau::dopri5(),
//!                      &IntegrateOpts::default()).unwrap();
//! println!("steps: {} nfe: {}", traj.len(), traj.nfe);
//! ```
//!
//! ## Batched solving
//!
//! `B` independent solves of the same dynamics advance together — each to
//! its **own endpoint** if desired ([`ode::integrate_batch_spans`]); all
//! per-sample results are bit-identical to `B` scalar [`ode::integrate`]
//! calls over the same spans:
//!
//! ```no_run
//! use nodal::grad::aca_backward_batch;
//! use nodal::ode::{analytic::VanDerPol, integrate_batch_spans, tableau, IntegrateOpts};
//!
//! let f = VanDerPol::new(0.15);
//! let z0 = [2.0f32, 0.0, -1.5, 0.5]; // B = 2 samples × D = 2, row-major
//! let bt = integrate_batch_spans(&f, 0.0, &[5.0, 3.0], &z0, tableau::dopri5(),
//!                                &IntegrateOpts::default()).unwrap();
//! let lam = [1.0f32, 0.0, 1.0, 0.0]; // dL/dz(T) per sample
//! let grads = aca_backward_batch(&f, tableau::dopri5(), &bt, &lam);
//! println!("sample 0: steps {} nfe {} dL/dz0 {:?}",
//!          bt.steps(0), bt.tracks[0].nfe, grads[0].dl_dz0);
//! ```
//!
//! ## Memory-budgeted checkpoints
//!
//! A long-horizon solve no longer has to hold every accepted state: give
//! the solve a byte budget and the store keeps sparse anchors, replaying
//! dropped states bit-exactly when the backward pass asks for them —
//! gradients are bit-identical to the dense store ([`ckpt`]):
//!
//! ```no_run
//! use nodal::ckpt::CkptPolicy;
//! use nodal::grad::aca_backward;
//! use nodal::ode::{analytic::VanDerPol, integrate, tableau, IntegrateOpts};
//!
//! let f = VanDerPol::new(0.15);
//! let opts = IntegrateOpts {
//!     ckpt: CkptPolicy::Budgeted(4 * 1024), // ≤ 4 KiB of state anchors
//!     ..IntegrateOpts::default()
//! };
//! let traj = integrate(&f, 0.0, 100.0, &[2.0, 0.0], tableau::dopri5(), &opts).unwrap();
//! let g = aca_backward(&f, tableau::dopri5(), &traj, &[1.0, 0.0]);
//! println!("bytes {} replay-nfe {}", traj.checkpoint_bytes(), g.meter.nfe_replay);
//! ```
//!
//! ## Serving
//!
//! Concurrent solve requests from independent callers coalesce dynamically
//! ([`serve`]); per-request results are exactly what a direct solve returns:
//!
//! ```no_run
//! use nodal::ode::analytic::VanDerPol;
//! use nodal::serve::{Lane, SolveRequest, SolveServer};
//!
//! let server = SolveServer::builder().register("vdp", VanDerPol::new(0.15)).start();
//! let req = SolveRequest::builder("vdp")
//!     .span(0.0, 25.0)
//!     .state(vec![2.0, 0.0])
//!     .adaptive(1e-6, 1e-8)
//!     .observe_at(vec![5.0, 10.0, 25.0]) // optional dense-output grid
//!     .priority(Lane::Interactive)
//!     .build()
//!     .unwrap();
//! let resp = server.submit(req).unwrap().wait().unwrap();
//! println!("z(T) = {:?}  nfe {}  batched with {} requests",
//!          resp.z_t1(), resp.stats.nfe, resp.stats.batch_size);
//! println!("observed: {:?}", resp.observations());
//! println!("{}", server.metrics());
//! ```
//!
//! The typed builder validates at `build()` (finite spans, nonzero
//! tolerances, in-span observation grids), so malformed requests never
//! reach admission. **QoS model:** every request carries a [`serve::Lane`]
//! — `Interactive` (the default) flushes before `Batch` on every emission
//! round — and the batch former schedules tenants (dynamics keys) by
//! deficit round-robin under the `NODAL_SERVE_QUOTA_*` knobs, so one
//! tenant's flood cannot starve another's queue. Scheduling only reorders
//! *emission*: per-request results stay bit-identical to direct solves.
//! Fairness is observable per tenant via the `per_key_queue_wait` p99
//! histograms in [`serve::MetricsSnapshot`]. A request may also attach a
//! dense-output observation grid (`observe_at`): the response carries the
//! trajectory interpolated at those times, each point bit-equal to
//! [`ode::dense::DenseOutput`] evaluation on a direct solve.
//!
//! ## HTTP front door
//!
//! The same server speaks HTTP/1.1 over a vendored `std::net` front end
//! ([`serve::HttpServer`]) — no framework, fully offline. Requests and
//! responses use the versioned JSON wire schema ([`serve::WIRE_VERSION`];
//! unknown versions are a typed [`serve::WireVersionError`]) shared with
//! the `dist` transport, f32 payloads travelling as u32 bit patterns.
//! `Overloaded` admission maps to `429` with a `Retry-After` header;
//! malformed or oversized traffic bounces with `400` before admission:
//!
//! ```no_run
//! use nodal::ode::analytic::VanDerPol;
//! use nodal::serve::{HttpConfig, HttpServer, SolveServer};
//! use std::sync::Arc;
//!
//! let server = Arc::new(SolveServer::builder().register("vdp", VanDerPol::new(0.15)).start());
//! let http = HttpServer::spawn(server, HttpConfig::from_env()).unwrap();
//! println!("POST solves to http://{}/v1/solve", http.addr());
//! // curl -s localhost:7118/healthz
//! // curl -s -X POST localhost:7118/v1/solve -d @request.json
//! // curl -s localhost:7118/v1/metrics
//! ```
//!
//! ## Distributed scale-out
//!
//! Both halves of the crate scale past one process over a shared framed
//! TCP transport ([`dist`]), configured by the `NODAL_DIST_*` knobs
//! ([`dist::env::DistConfig`]):
//!
//! * **Data-parallel training** ([`dist::train`], surfaced as
//!   [`train::distributed`]) — every rank computes its deterministic
//!   contiguous shard of the mini-batch locally (batched forward +
//!   shared-stage ACA backward), and rank 0 combines the partials with a
//!   fixed adjacent-pairwise **tree reduction** keyed by rank slot, never
//!   by arrival order. A W-rank step is therefore bit-identical run to
//!   run and bit-identical to the single-process
//!   [`dist::train::grad_accum_reference`] fold — the distributed analog
//!   of ACA's batch-composition invariance. Small parameter leaves are
//!   bucketed into grouped wire payloads; dead workers are evicted and
//!   the batch re-partitions deterministically over the survivors
//!   (`cargo bench --bench dist_reduce` tracks reduce throughput and
//!   payload counts).
//! * **Sharded serving** ([`dist::shard`], [`dist::dispatch`]) — each
//!   shard is a [`serve::SolveServer`] behind a TCP endpoint; the
//!   [`dist::Dispatcher`] routes requests by batch-key hash so
//!   coalescing survives sharding, steals work past a load margin,
//!   propagates `Overloaded` backpressure end-to-end, re-dispatches the
//!   pending requests of a dead shard to the survivors, and merges
//!   per-shard metrics into one fleet report.
//!
//! All f32 payloads travel as bit patterns ([`util::json`]), so NaN,
//! `-0.0` and infinities survive the wire bit-exactly. The whole
//! subsystem is exercised in-process on loopback sockets
//! (`tests/dist_integration.rs`) and as a real two-process smoke in CI
//! (`examples/dist_train.rs`).
//!
//! ## Observability
//!
//! Every layer above emits **structured spans** into [`obs`], a
//! zero-dependency tracing subsystem, so one request's cost decomposes
//! exactly the way the paper argues about cost. The span taxonomy follows
//! a solve through the stack: `http_request` → `admission` →
//! `queue_wait` (per request, with its QoS lane and DRR deferral count) →
//! `batch_form` (flush reason and size) → `solve` → `forward` (active-set
//! rounds, `eval_batch` stage sweeps, NFE) → `reverse` (reverse rounds,
//! `vjp_batch` sweeps, NFE) → `replay` (`SegmentCache` recompute cost,
//! `nfe_replay` attributed); `dispatch`/`steal`/`failover` events mark
//! dist routing, and shard-side spans carry their shard id so a
//! [`dist::Dispatcher`]-routed solve stitches into **one cross-process
//! trace** (span context rides inside the wire frames). Per-span NFE
//! attribution sums to the request's `CostMeter` totals.
//!
//! **Sampling:** the HTTP front door traces any request carrying an
//! `x-nodal-trace` header (echoed back on the response), and every Nth
//! unsolicited request when `NODAL_TRACE_SAMPLE_N` > 0. Traces are served
//! live at `GET /v1/trace/<id>` and exported as deterministic JSONL under
//! `NODAL_TRACE_DIR` (default `<results>/trace/`); `GET /v1/metrics`
//! additionally speaks Prometheus text exposition (`Accept: text/plain`
//! or `GET /metrics`), histograms included as cumulative buckets.
//!
//! **Answer-neutrality contract:** tracing never touches the float path.
//! Span timestamps come only from the injected [`serve::Clock`] (traces
//! are deterministic under a `ManualClock`), hot loops contain only
//! thread-local integer counters ([`obs::hot_count`]), and span emission
//! happens outside the solver loops against a preallocated per-thread
//! recorder — so solves with tracing on and off are **bit-identical**
//! (grids, finals, gradients, meters; property-tested across all four
//! analytic dynamics), and disabled tracing costs a few integer adds.
//!
//! ## Invariants (machine-checked by `nodal-lint`)
//!
//! Everything above rests on one guarantee: **the reverse pass replays the
//! exact float computation the forward pass recorded** (ACA bit-exactness),
//! and solver results depend only on inputs — never on wall time, hash
//! order, or an environment variable read mid-solve. These invariants are
//! enforced by an offline static-analysis pass, `cargo run -p nodal-lint`
//! (a CI hard gate; report at `results/lint/report.jsonl`), with eight
//! rules — the last three interprocedural, driven by an intra-crate call
//! graph (see `nodal-lint`'s `graph` module for its construction and
//! documented limits):
//!
//! 1. **env-knob** — `std::env::var` is read only inside the designated
//!    parse-and-clamp helpers
//!    ([`coordinator::pool::default_workers`],
//!    [`coordinator::report`]'s `results_dir`, [`runtime`]'s
//!    `artifact_root`, [`ckpt`]'s budget parsers, the `env_clamped`
//!    helpers in [`serve`] and its HTTP front door,
//!    [`dist::env`]'s `from_env`/`env_usize`, [`obs::trace_env`]), and every
//!    `NODAL_*` knob mentioned anywhere in the sources must appear in the
//!    table below.
//! 2. **determinism** — `Instant::now`/`SystemTime::now` only behind the
//!    injected [`serve::Clock`] or in benchmark/timing modules; no
//!    `HashMap`/`HashSet` in [`ode`], [`grad`], [`ckpt`] (iteration order
//!    must never shape a trajectory or a gradient).
//! 3. **hot-alloc** — regions marked `// nodal-lint: hot` (the stage sweeps
//!    and solver inner loops) may not allocate: no `vec!`/`Vec::new`/
//!    `with_capacity`/`collect`/`clone`/`to_vec`/`Box::new`/`String`
//!    constructors inside the marked block.
//! 4. **panic-isolation** — no `unwrap`/`expect`/`panic!` family and no
//!    uncommented constant index in non-test [`serve`] or [`dist`] code
//!    (one poisoned request must degrade, never take down a worker or a
//!    rank); the `lock()/wait()` poison idiom is exempt.
//! 5. **parity-linkage** — every non-test [`ode::OdeFunc`] impl overriding
//!    `eval_batch`/`vjp_batch` must be named in a bit-equality test tying
//!    the batched path to the scalar one.
//! 6. **lock-discipline** — in [`dist`] and [`serve`], no mutex guard may
//!    live across a blocking call (socket I/O, `join`, `sleep`), directly
//!    or through any function the call graph can reach (a stalled peer
//!    must never stall every thread sharing the lock); and any pair of
//!    locks taken nested must be taken in one consistent order everywhere
//!    (no ABBA deadlock shapes).
//! 7. **wire-determinism** — in [`dist`], floats reach the wire only as
//!    `u32`/`u64` bit patterns ([`util::json`]'s `f32_bits` family) —
//!    never as float JSON (`Json::Num` / `.as_f64()`), whose text
//!    round-trip would silently drop NaN payloads and `-0.0`.
//! 8. **transitive hot-alloc** — rule 3 extended through the call graph:
//!    a function reachable from a `// nodal-lint: hot` region may not
//!    allocate either, so hoisting an allocation into a helper does not
//!    launder it off the hot path. Method calls with several same-named
//!    candidates are counted as unresolved in the report, never guessed.
//!
//! A violation is suppressed only by `// nodal-lint: allow(<rule>)
//! <reason>` with a non-empty reason; a bare `allow` is itself a
//! diagnostic.
//!
//! ### Environment knobs
//!
//! The complete set of `NODAL_*` environment variables (the env-knob rule
//! fails on any knob not listed here):
//!
//! | knob | reader | meaning | default, clamp |
//! |------|--------|---------|----------------|
//! | `NODAL_WORKERS` | [`coordinator::pool::default_workers`] | coordinator pool threads | available cores, 1..=256 |
//! | `NODAL_RESULTS` | `coordinator::report::results_dir` | results/report root directory | `results/` |
//! | `NODAL_ARTIFACTS` | `runtime::artifact_root` | AOT artifact directory | `artifacts/` |
//! | `NODAL_CKPT_BUDGET_BYTES` | [`ckpt::env_budget_bytes`] | per-sample checkpoint budget (0 = dense) | 0, 0 or 64..=2⁴⁰ |
//! | `NODAL_SERVE_MAX_BATCH` | [`serve::ServeConfig::from_env`] | max samples per served batch | 16, 1..=1024 |
//! | `NODAL_SERVE_MAX_DELAY_US` | [`serve::ServeConfig::from_env`] | max queue delay (µs) | 500, 0..=10⁶ |
//! | `NODAL_SERVE_QUEUE_CAP` | [`serve::ServeConfig::from_env`] | admitted-unanswered cap | 1024, 1..=10⁶ |
//! | `NODAL_SERVE_WORKERS` | [`serve::ServeConfig::from_env`] | serve worker threads | pool default, 1..=256 |
//! | `NODAL_SERVE_MEM_BUDGET_BYTES` | [`serve::ServeConfig::from_env`] | projected-checkpoint admission budget (0 = unlimited) | 0, 0 or 64..=2⁴⁰ |
//! | `NODAL_SERVE_QUOTA_QUANTUM` | [`serve::ServeConfig::from_env`] | DRR quantum: batches a tenant may emit per scheduling round | 32, 1..=1024 |
//! | `NODAL_SERVE_QUOTA_MAX_DEFICIT` | [`serve::ServeConfig::from_env`] | cap on a tenant's banked DRR deficit | 128, 1..=10⁶ |
//! | `NODAL_HTTP_PORT` | [`serve::HttpConfig::from_env`] | HTTP front-door port on 127.0.0.1 | 7118, 1..=65535 |
//! | `NODAL_HTTP_MAX_BODY_BYTES` | [`serve::HttpConfig::from_env`] | largest accepted HTTP request body | 1 MiB, 1 KiB..=64 MiB |
//! | `NODAL_TRACE_SAMPLE_N` | [`obs::trace_env`] | trace every Nth unsolicited HTTP request (0 = header-only) | 0, 0..=10⁶ |
//! | `NODAL_TRACE_DIR` | [`obs::trace_env`] | trace JSONL export directory | `<results>/trace` |
//! | `NODAL_DIST_RANK` | [`dist::env::DistConfig::from_env`] | this process's rank | 0, 0..=world−1 |
//! | `NODAL_DIST_WORLD_SIZE` | [`dist::env::DistConfig::from_env`] | ranks in the training world | 1, 1..=256 |
//! | `NODAL_DIST_PORT` | [`dist::env::DistConfig::from_env`] | rank-0 coordinator port | 7117, 1..=65535 |
//! | `NODAL_DIST_HOSTS` | [`dist::env::DistConfig::from_env`] | comma-separated host list (first entry is rank 0) | loopback |

pub mod bench;
pub mod ckpt;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod grad;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod ode;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
