//! # nodal — Adaptive Checkpoint Adjoint gradient estimation for Neural ODEs
//!
//! Rust + JAX + Pallas reproduction of *"Adaptive Checkpoint Adjoint Method for
//! Gradient Estimation in Neural ODE"* (Zhuang et al., ICML 2020).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — adaptive explicit Runge–Kutta solving with PI
//!   step-size control ([`ode`]), the paper's trajectory-checkpoint data
//!   structure and the three gradient-estimation strategies — **naive**,
//!   **adjoint**, **ACA** ([`grad`]) — plus training ([`train`]), data
//!   generation ([`data`]), metrics ([`metrics`]) and the experiment
//!   coordinator ([`coordinator`]).
//! * **L2 (JAX, `python/compile/model.py`)** — model dynamics `f(z, t, θ)`,
//!   encoders/decoders/loss heads, AOT-lowered to HLO text.
//! * **L1 (Pallas, `python/compile/kernels/`)** — fused hot-path kernels
//!   called from the L2 graphs.
//!
//! At runtime the coordinator executes the AOT artifacts through PJRT
//! ([`runtime`]); Python never runs on the training path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use nodal::ode::{analytic::VanDerPol, integrate, IntegrateOpts, tableau};
//!
//! let f = VanDerPol::new(0.15);
//! let traj = integrate(&f, 0.0, 25.0, &[2.0, 0.0], tableau::dopri5(),
//!                      &IntegrateOpts::default()).unwrap();
//! println!("steps: {} nfe: {}", traj.len(), traj.nfe);
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod grad;
pub mod metrics;
pub mod models;
pub mod ode;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
