//! `nodal` — launcher for the ACA Neural-ODE framework.
//!
//! Subcommands:
//!   repro <id> [--key value …]   regenerate a paper table/figure (see `list`)
//!   list                          list reproducible experiments
//!   info                          runtime + artifact status
//!
//! Every experiment accepts `--config file.json` plus `--key value`
//! overrides; see `rust/src/config`.

use anyhow::Result;

use nodal::config::Config;
use nodal::coordinator;

fn usage() -> ! {
    eprintln!(
        "usage: nodal <command>\n\
         \n\
         commands:\n\
           repro <id> [--key value …]   run an experiment (or `repro all`)\n\
           list                          list experiments\n\
           info                          show runtime + artifact status\n"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("list") => {
            println!("experiments (nodal repro <id>):");
            for (id, desc) in coordinator::EXPERIMENTS {
                println!("  {id:<8} {desc}");
            }
            println!("  all      run everything in sequence");
            Ok(())
        }
        Some("info") => {
            let engine = nodal::runtime::Engine::cpu()?;
            println!("PJRT platform : {}", engine.platform());
            let root = nodal::runtime::artifact_root();
            println!("artifact root : {}", root.display());
            let mut n = 0;
            if let Ok(dirs) = std::fs::read_dir(&root) {
                for d in dirs.flatten() {
                    if d.path().join("manifest.json").exists() {
                        let m = nodal::runtime::Manifest::load(&d.path())?;
                        println!(
                            "  {:<12} kind={:<10} P={:<6} B={}",
                            m.name, m.kind, m.n_params, m.batch
                        );
                        n += 1;
                    }
                }
            }
            if n == 0 {
                println!("  (no artifacts — run `make artifacts`)");
            }
            println!("results dir   : {}", coordinator::results_dir().display());
            Ok(())
        }
        Some("repro") => {
            let id = args.get(1).cloned().unwrap_or_else(|| usage());
            let mut cfg = Config::new();
            cfg.apply_args(&args[2..])?;
            coordinator::run(&id, &cfg)
        }
        _ => usage(),
    }
}
