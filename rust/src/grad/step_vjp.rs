//! Exact discrete adjoint of one RK step — the "local backward" of the
//! paper's Algo 2.
//!
//! For a tableau `(A, b, c)` the step is
//!
//! ```text
//! u_j = z + h Σ_{l<j} a_jl k_l        k_j = f(t + c_j h, u_j)
//! y   = z + h Σ_j b_j k_j
//! ```
//!
//! Given `λ = dL/dy`, the reverse sweep computes `dL/dz`, accumulates
//! `dL/dθ`, and (for the naive method) the *explicit* `dL/dh`:
//!
//! ```text
//! k̄_j  = h b_j λ                                    (seed)
//! for j = s−1 .. 0:
//!     w_j   = k̄_j
//!     ŵ_j  = w_jᵀ ∂f/∂u |_{u_j}      (one VJP; also yields w_jᵀ ∂f/∂θ)
//!     dz   += ŵ_j ;   k̄_l += h a_jl ŵ_j  (l < j)
//! dz += λ
//! dh  = λ·Σ_j b_j k_j + Σ_j ŵ_j·Σ_{l<j} a_jl k_l    (f autonomous: no ∂f/∂t)
//! ```
//!
//! The stages are recomputed from the checkpoint (`m+1`-th evaluation in the
//! paper's cost accounting) and freed immediately — "delete local
//! computation graphs".

use crate::ode::func::OdeFunc;
use crate::ode::tableau::Tableau;
use crate::tensor;

/// Output of a step VJP.
#[derive(Debug, Clone)]
pub struct StepVjp {
    /// `dL/dz` at the step's start state.
    pub dz: Vec<f32>,
    /// Explicit `dL/dh` (0 unless requested).
    pub dh: f64,
    /// `f` evaluations spent recomputing stages.
    pub nfe: usize,
    /// VJP calls spent.
    pub nvjp: usize,
}

/// Recompute the stages of a step from `(t, h, z)`.
///
/// Returns `(us, ks)` where `us[j]` is the stage input and `ks[j]` the stage
/// derivative.
fn recompute_stages<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    z: &[f32],
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let s = tab.stages;
    let dim = z.len();
    let mut us: Vec<Vec<f32>> = Vec::with_capacity(s);
    let mut ks: Vec<Vec<f32>> = Vec::with_capacity(s);
    for j in 0..s {
        let mut u = z.to_vec();
        for (l, a) in tab.a[j].iter().enumerate() {
            if *a != 0.0 {
                tensor::axpy((h * *a) as f32, &ks[l], &mut u);
            }
        }
        let mut k = vec![0.0f32; dim];
        f.eval(t + tab.c[j] * h, &u, &mut k);
        us.push(u);
        ks.push(k);
    }
    (us, ks)
}

/// Shared reverse sweep: given per-stage seeds `k̄_j` (`bar_k`), run the
/// stage-reverse recursion. Adds the result into `dz` and `dtheta`, returns
/// the Σ_j ŵ_j · (Σ_{l<j} a_jl k_l) term of `dh` plus vjp count.
#[allow(clippy::too_many_arguments)]
fn reverse_sweep<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    us: &[Vec<f32>],
    ks: &[Vec<f32>],
    mut bar_k: Vec<Vec<f32>>,
    dz: &mut [f32],
    dtheta: &mut [f32],
    want_dh: bool,
) -> (f64, usize) {
    let s = tab.stages;
    let dim = dz.len();
    let mut wjz = vec![0.0f32; dim];
    let mut dh_inner = 0.0f64;
    let mut nvjp = 0usize;
    for j in (0..s).rev() {
        // Skip dead stages (seed exactly zero and no downstream contribution).
        if bar_k[j].iter().all(|&v| v == 0.0) {
            continue;
        }
        f.vjp(t + tab.c[j] * h, &us[j], &bar_k[j], &mut wjz, dtheta);
        nvjp += 1;
        tensor::axpy(1.0, &wjz, dz);
        for (l, a) in tab.a[j].iter().enumerate() {
            if *a != 0.0 {
                let (lo, _) = bar_k.split_at_mut(j);
                tensor::axpy((h * *a) as f32, &wjz, &mut lo[l]);
            }
        }
        if want_dh {
            // ŵ_j · (Σ_{l<j} a_jl k_l) = ŵ_j · (u_j − z)/h ; use the a-form
            // to stay exact when h is tiny.
            let mut acc = 0.0f64;
            for (l, a) in tab.a[j].iter().enumerate() {
                if *a != 0.0 {
                    acc += *a * tensor::dot(&wjz, &ks[l]);
                }
            }
            dh_inner += acc;
        }
    }
    (dh_inner, nvjp)
}

/// Exact VJP of `ψ_h(t, z)` (see module docs). `dtheta` is accumulated into.
pub fn step_vjp<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    z: &[f32],
    lam: &[f32],
    dtheta: &mut [f32],
    want_dh: bool,
) -> StepVjp {
    let s = tab.stages;
    let dim = z.len();
    let (us, ks) = recompute_stages(f, tab, t, h, z);

    // Seed: k̄_j = h b_j λ.
    let bar_k: Vec<Vec<f32>> = (0..s)
        .map(|j| {
            if tab.b[j] == 0.0 {
                vec![0.0f32; dim]
            } else {
                lam.iter().map(|&l| (h * tab.b[j]) as f32 * l).collect()
            }
        })
        .collect();

    let mut dz = vec![0.0f32; dim];
    let (dh_inner, nvjp) =
        reverse_sweep(f, tab, t, h, &us, &ks, bar_k, &mut dz, dtheta, want_dh);

    // Direct z path of y = z + ...
    tensor::axpy(1.0, lam, &mut dz);

    let dh = if want_dh {
        // λ · Σ_j b_j k_j
        let mut d = 0.0f64;
        for j in 0..s {
            if tab.b[j] != 0.0 {
                d += tab.b[j] * tensor::dot(lam, &ks[j]);
            }
        }
        d + dh_inner
    } else {
        0.0
    };

    StepVjp { dz, dh, nfe: s, nvjp }
}

/// Reusable buffers for [`step_vjp_batch`] — one allocation for the whole
/// reverse sweep instead of fresh stage vectors per step per sample (the
/// per-call `Vec<Vec<f32>>` of the scalar [`step_vjp`] is what the shared
/// sweep amortizes away, alongside the per-sample dispatch).
#[derive(Debug, Default)]
pub struct StepVjpBatchScratch {
    /// Stage inputs `u_j`, one packed `[n × dim]` buffer per stage.
    us: Vec<Vec<f32>>,
    /// Stage derivatives `k_j`, same layout.
    ks: Vec<Vec<f32>>,
    /// Reverse seeds `k̄_j`, same layout.
    bar_k: Vec<Vec<f32>>,
    /// Per-sample stage times for the `eval_batch` sweep.
    ts_stage: Vec<f64>,
    /// Samples whose seed for the current stage is non-zero.
    live: Vec<usize>,
    /// Packed live-sample buffers for the `vjp_batch` sweep.
    ts_live: Vec<f64>,
    us_live: Vec<f32>,
    ws_live: Vec<f32>,
    wjz_live: Vec<f32>,
    wjp_live: Vec<f32>,
}

impl StepVjpBatchScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, stages: usize, n: usize, dim: usize, n_params: usize) {
        for buf in [&mut self.us, &mut self.ks, &mut self.bar_k] {
            while buf.len() < stages {
                buf.push(Vec::new());
            }
            for b in buf.iter_mut().take(stages) {
                if b.len() < n * dim {
                    b.resize(n * dim, 0.0);
                }
            }
        }
        if self.ts_stage.len() < n {
            self.ts_stage.resize(n, 0.0);
            self.ts_live.resize(n, 0.0);
        }
        if self.us_live.len() < n * dim {
            self.us_live.resize(n * dim, 0.0);
            self.ws_live.resize(n * dim, 0.0);
            self.wjz_live.resize(n * dim, 0.0);
        }
        if self.wjp_live.len() < n * n_params {
            self.wjp_live.resize(n * n_params, 0.0);
        }
        self.live.reserve(n);
    }
}

/// Shared-stage batched counterpart of [`step_vjp`]: run the stage
/// recomputation and reverse ŵ-sweep for `n` samples that share a reverse
/// step index, with one [`OdeFunc::eval_batch`] call per stage (forward
/// recompute) and one [`OdeFunc::vjp_batch`] call per live stage (reverse)
/// instead of `n` scalar calls each.
///
/// Inputs are packed row-major: `ts`/`hs` are each sample's step start time
/// and step size (`[n]`), `zs` the step-start states and `lams` the incoming
/// cotangents (`[n × dim]`). Times, step sizes and signs are fully
/// independent per sample — co-batched samples never need to share a span
/// (or even a direction), which is what lets `aca_backward_batch` replay
/// [`integrate_batch_spans`](crate::ode::integrate_batch_spans)
/// trajectories unchanged.
///
/// Outputs, per sample `i`:
/// * `dzs` row `i` is **overwritten** with `dL/dz` at the step's start;
/// * `dthetas` row `i` (`[n × n_params]`) is **accumulated into**, one
///   stage-contribution at a time — the identical floating-point sequence
///   the scalar `step_vjp` applies to its `dtheta`, so per-sample parameter
///   gradients stay bit-identical;
/// * `nvjps[i]` is incremented by the sample's VJP count (dead stages —
///   seed exactly zero — are skipped per sample, matching the scalar
///   short-circuit and its meter accounting).
///
/// Returns the `f` evaluations spent *per sample* (= `tab.stages`, as in
/// the scalar path). Explicit `dL/dh` is not offered here: only the naive
/// method consumes it, and that method has no shared-stage formulation.
// nodal-lint: hot
#[allow(clippy::too_many_arguments)]
pub fn step_vjp_batch<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    ts: &[f64],
    hs: &[f64],
    zs: &[f32],
    lams: &[f32],
    dzs: &mut [f32],
    dthetas: &mut [f32],
    nvjps: &mut [usize],
    scratch: &mut StepVjpBatchScratch,
) -> usize {
    let s = tab.stages;
    let n = ts.len();
    let dim = f.dim();
    let p = f.n_params();
    debug_assert_eq!(hs.len(), n);
    debug_assert_eq!(zs.len(), n * dim);
    debug_assert_eq!(lams.len(), n * dim);
    debug_assert_eq!(dzs.len(), n * dim);
    debug_assert_eq!(dthetas.len(), n * p);
    debug_assert_eq!(nvjps.len(), n);
    scratch.ensure(s, n, dim, p);

    // ---- forward: recompute all stages, one eval_batch per stage ----
    for j in 0..s {
        let (ks_lo, ks_hi) = scratch.ks.split_at_mut(j);
        let u_j = &mut scratch.us[j];
        for i in 0..n {
            let u = &mut u_j[i * dim..(i + 1) * dim];
            u.copy_from_slice(&zs[i * dim..(i + 1) * dim]);
            for (l, a) in tab.a[j].iter().enumerate() {
                if *a != 0.0 {
                    tensor::axpy((hs[i] * *a) as f32, &ks_lo[l][i * dim..(i + 1) * dim], u);
                }
            }
            scratch.ts_stage[i] = ts[i] + tab.c[j] * hs[i];
        }
        f.eval_batch(&scratch.ts_stage[..n], &u_j[..n * dim], &mut ks_hi[0][..n * dim]);
    }

    // ---- seeds: k̄_j = h b_j λ, per sample ----
    for j in 0..s {
        let bk = &mut scratch.bar_k[j];
        if tab.b[j] == 0.0 {
            bk[..n * dim].fill(0.0);
        } else {
            for i in 0..n {
                let hb = (hs[i] * tab.b[j]) as f32;
                for (o, &l) in
                    bk[i * dim..(i + 1) * dim].iter_mut().zip(&lams[i * dim..(i + 1) * dim])
                {
                    *o = hb * l;
                }
            }
        }
    }

    // ---- reverse ŵ-sweep: one vjp_batch over the live samples per stage ----
    dzs[..n * dim].fill(0.0);
    for j in (0..s).rev() {
        scratch.live.clear();
        {
            // Skip dead stages per sample (seed exactly zero and no
            // downstream contribution) — same short-circuit as the scalar
            // sweep, so per-sample VJP meters agree.
            let bk = &scratch.bar_k[j];
            for i in 0..n {
                if bk[i * dim..(i + 1) * dim].iter().any(|&v| v != 0.0) {
                    scratch.live.push(i);
                }
            }
        }
        if scratch.live.is_empty() {
            continue;
        }
        let nl = scratch.live.len();
        for (q, &i) in scratch.live.iter().enumerate() {
            scratch.ts_live[q] = ts[i] + tab.c[j] * hs[i];
            scratch.us_live[q * dim..(q + 1) * dim]
                .copy_from_slice(&scratch.us[j][i * dim..(i + 1) * dim]);
            scratch.ws_live[q * dim..(q + 1) * dim]
                .copy_from_slice(&scratch.bar_k[j][i * dim..(i + 1) * dim]);
            // Gather the running dθ rows so the vjp accumulates straight
            // onto them (scatter-back below is a bit-preserving copy).
            scratch.wjp_live[q * p..(q + 1) * p].copy_from_slice(&dthetas[i * p..(i + 1) * p]);
        }
        f.vjp_batch(
            &scratch.ts_live[..nl],
            &scratch.us_live[..nl * dim],
            &scratch.ws_live[..nl * dim],
            &mut scratch.wjz_live[..nl * dim],
            &mut scratch.wjp_live[..nl * p],
        );
        let (bk_lo, _) = scratch.bar_k.split_at_mut(j);
        for (q, &i) in scratch.live.iter().enumerate() {
            let wjz = &scratch.wjz_live[q * dim..(q + 1) * dim];
            tensor::axpy(1.0, wjz, &mut dzs[i * dim..(i + 1) * dim]);
            for (l, a) in tab.a[j].iter().enumerate() {
                if *a != 0.0 {
                    tensor::axpy((hs[i] * *a) as f32, wjz, &mut bk_lo[l][i * dim..(i + 1) * dim]);
                }
            }
            dthetas[i * p..(i + 1) * p].copy_from_slice(&scratch.wjp_live[q * p..(q + 1) * p]);
            nvjps[i] += 1;
        }
    }

    // Direct z path of y = z + ...
    for i in 0..n {
        tensor::axpy(1.0, &lams[i * dim..(i + 1) * dim], &mut dzs[i * dim..(i + 1) * dim]);
    }
    s
}

/// VJP of the *error norm* of a step attempt — the quantity the naive method
/// backpropagates through the step-size controller (paper Sec 3.3).
///
/// `err = sqrt(mean_i (ev_i / sc_i)²)` with `ev = h Σ_j e_j k_j` and
/// `sc_i = atol + rtol·|z_i|`. Both paths are differentiated: through the
/// error vector (stage reverse sweep) and through the tolerance scale
/// (`∂err/∂z_i ⊇ −ev_i²·rtol·sign(z_i)/(sc_i³·N·err)`) — the latter is what
/// makes the error norm nearly scale-invariant for homogeneous dynamics, so
/// dropping it would bias the naive method's h-chain.
///
/// Scales everything by the upstream gradient `gbar = dL/derr`; adds into
/// `dz_accum`/`dtheta`, returns `dL/dh` (explicit) plus costs.
#[allow(clippy::too_many_arguments)]
pub fn err_norm_vjp<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    z: &[f32],
    atol: f64,
    rtol: f64,
    gbar: f64,
    dz_accum: &mut [f32],
    dtheta: &mut [f32],
) -> (f64, usize, usize) {
    let e = tab
        .b_err
        .expect("err_norm_vjp requires an adaptive tableau");
    let s = tab.stages;
    let dim = z.len();
    let (us, ks) = recompute_stages(f, tab, t, h, z);

    // Recompute the error vector (the scale uses the start state only —
    // matching rk_step — so `err` has no z_next dependence).
    let mut ev = vec![0.0f32; dim];
    for (c, k) in e.iter().zip(&ks) {
        if *c != 0.0 {
            tensor::axpy((h * *c) as f32, k, &mut ev);
        }
    }
    let err = tensor::wrms_norm(&ev, z, z, atol, rtol);
    if err <= 0.0 || !err.is_finite() {
        return (0.0, s, 0);
    }

    // d err / d ev_i = ev_i / (sc_i² · N · err).
    let n = dim as f64;
    let w_ev: Vec<f32> = (0..dim)
        .map(|i| {
            let sc = atol + rtol * z[i].abs() as f64;
            ((ev[i] as f64 / (sc * sc)) / (n * err) * gbar) as f32
        })
        .collect();

    // Seed k̄_j = h e_j w_ev.
    let bar_k: Vec<Vec<f32>> = (0..s)
        .map(|j| {
            if e[j] == 0.0 {
                vec![0.0f32; dim]
            } else {
                w_ev.iter().map(|&l| (h * e[j]) as f32 * l).collect()
            }
        })
        .collect();

    let mut dz = vec![0.0f32; dim];
    let (dh_inner, nvjp) = reverse_sweep(f, tab, t, h, &us, &ks, bar_k, &mut dz, dtheta, true);
    tensor::axpy(1.0, &dz, dz_accum);

    // Direct tolerance-scale path: ∂err/∂z_i = −ev_i²·rtol·sign(z_i)/(sc_i³·N·err).
    if rtol != 0.0 {
        for i in 0..dim {
            if z[i] == 0.0 {
                continue; // sub-gradient of |z| at 0
            }
            let sc = atol + rtol * z[i].abs() as f64;
            let evi = ev[i] as f64;
            let d = -(evi * evi) * rtol * z[i].signum() as f64 / (sc * sc * sc * n * err);
            dz_accum[i] += (gbar * d) as f32;
        }
    }

    // Explicit h path of ev = h Σ e_j k_j.
    let mut dh = dh_inner;
    for j in 0..s {
        if e[j] != 0.0 {
            dh += e[j] * tensor::dot(&w_ev, &ks[j]);
        }
    }
    (dh, s, nvjp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::step::{rk_step, StepScratch};
    use crate::ode::tableau;

    /// For dz/dt = kz one RK step is linear: y = R(kh) z with a rational
    /// stability polynomial. The VJP w.r.t. z must be R(kh) · λ.
    #[test]
    fn linear_step_vjp_exact() {
        let k = -0.8f64;
        let f = Linear::new(k as f32, 1);
        for tab in [tableau::euler(), tableau::rk4(), tableau::dopri5()] {
            let h = 0.3f64;
            // Stability polynomial R = Σ_i (kh)^i / i! truncated at order.
            // Compute R numerically by stepping z=1.
            let mut y = [0.0f32];
            let mut scratch = StepScratch::new();
            rk_step(&f, tab, 0.0, h, &[1.0], None, 1e-9, 1e-9, &mut y, None, &mut scratch);
            let r = y[0] as f64;
            let lam = [2.5f32];
            let mut dtheta = vec![0.0f32; 1];
            let out = step_vjp(&f, tab, 0.0, h, &[1.0], &lam, &mut dtheta, false);
            assert!(
                (out.dz[0] as f64 - r * 2.5).abs() < 1e-5,
                "{}: dz {} vs R*lam {}",
                tab.name,
                out.dz[0],
                r * 2.5
            );
        }
    }

    /// Finite-difference check of dz, dθ, dh on a nonlinear system.
    #[test]
    fn step_vjp_matches_finite_difference() {
        let f = VanDerPol::new(0.15);
        let tab = tableau::dopri5();
        let t = 0.4;
        let h = 0.21;
        let z = [1.7f32, -0.3];
        let lam = [0.8f32, -1.2];
        let mut dtheta: Vec<f32> = vec![];
        let out = step_vjp(&f, tab, t, h, &z, &lam, &mut dtheta, true);

        let step = |zz: &[f32], hh: f64| -> f64 {
            let mut y = [0.0f32; 2];
            let mut s = StepScratch::new();
            rk_step(&f, tab, t, hh, zz, None, 1e-9, 1e-9, &mut y, None, &mut s);
            lam.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum()
        };

        // dz
        for i in 0..2 {
            let eps = 1e-3f32;
            let mut zp = z;
            zp[i] += eps;
            let mut zm = z;
            zm[i] -= eps;
            let fd = (step(&zp, h) - step(&zm, h)) / (2.0 * eps as f64);
            assert!(
                (out.dz[i] as f64 - fd).abs() < 2e-3 * fd.abs().max(1.0),
                "dz[{i}]: {} vs fd {}",
                out.dz[i],
                fd
            );
        }
        // dh (eps sized for f32 state noise: curvature error O(eps²) vs
        // roundoff O(1e-7/eps)).
        let eps = 1e-3;
        let fd_h = (step(&z, h + eps) - step(&z, h - eps)) / (2.0 * eps);
        assert!(
            (out.dh - fd_h).abs() < 5e-3 * fd_h.abs().max(1.0),
            "dh: {} vs fd {}",
            out.dh,
            fd_h
        );
    }

    /// dθ check on the linear system where dψ/dk is analytic-ish via FD.
    #[test]
    fn step_vjp_dtheta_matches_fd() {
        let tab = tableau::rk23();
        let h = 0.25f64;
        let z = [1.4f32, -0.6, 0.9];
        let lam = [1.0f32, 0.5, -0.25];
        let f = Linear::new(-0.9, 3);
        let mut dtheta = vec![0.0f32; 1];
        step_vjp(&f, tab, 0.0, h, &z, &lam, &mut dtheta, false);

        let loss_with_k = |k: f32| -> f64 {
            let fk = Linear::new(k, 3);
            let mut y = [0.0f32; 3];
            let mut s = StepScratch::new();
            rk_step(&fk, tab, 0.0, h, &z, None, 1e-9, 1e-9, &mut y, None, &mut s);
            lam.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let eps = 1e-3f32;
        let fd = (loss_with_k(-0.9 + eps) - loss_with_k(-0.9 - eps)) / (2.0 * eps as f64);
        assert!(
            (dtheta[0] as f64 - fd).abs() < 2e-3 * fd.abs().max(1.0),
            "dtheta {} vs fd {}",
            dtheta[0],
            fd
        );
    }

    /// dtheta accumulates across calls.
    #[test]
    fn dtheta_accumulates() {
        let f = Linear::new(0.5, 2);
        let tab = tableau::heun_euler();
        let mut dtheta = vec![0.0f32; 1];
        let z = [1.0f32, 2.0];
        let lam = [1.0f32, 1.0];
        step_vjp(&f, tab, 0.0, 0.1, &z, &lam, &mut dtheta, false);
        let first = dtheta[0];
        step_vjp(&f, tab, 0.0, 0.1, &z, &lam, &mut dtheta, false);
        assert!((dtheta[0] - 2.0 * first).abs() < 1e-6);
    }

    /// err_norm_vjp: finite-difference check of d err/d h and d err/d z.
    #[test]
    fn err_vjp_matches_finite_difference() {
        let f = VanDerPol::new(0.15);
        let tab = tableau::dopri5();
        let (t, h) = (0.0, 0.4);
        // Keep both components away from 0: |z| has a kink there and the
        // central FD of the scale path would be biased.
        let z = [2.0f32, 0.5];
        let (atol, rtol) = (1e-6, 1e-4);

        let err_of = |zz: &[f32], hh: f64| -> f64 {
            let mut y = [0.0f32; 2];
            let mut s = StepScratch::new();
            rk_step(&f, tab, t, hh, zz, None, atol, rtol, &mut y, None, &mut s).err_norm
        };

        let mut dz = vec![0.0f32; 2];
        let mut dtheta: Vec<f32> = vec![];
        let (dh, _, _) = err_norm_vjp(&f, tab, t, h, &z, atol, rtol, 1.0, &mut dz, &mut dtheta);

        let eps = 1e-4;
        let fd_h = (err_of(&z, h + eps) - err_of(&z, h - eps)) / (2.0 * eps);
        assert!(
            (dh - fd_h).abs() < 1e-2 * fd_h.abs().max(1e-9),
            "dh {} vs fd {}",
            dh,
            fd_h
        );

        for i in 0..2 {
            let eps = 1e-3f32;
            let mut zp = z;
            zp[i] += eps;
            let mut zm = z;
            zm[i] -= eps;
            let fd = (err_of(&zp, h) - err_of(&zm, h)) / (2.0 * eps as f64);
            assert!(
                (dz[i] as f64 - fd).abs() < 0.02 * fd.abs().max(1e-9),
                "dz[{i}] {} vs fd {}",
                dz[i],
                fd
            );
        }
    }

    /// Gradient seeds that are zero must cost zero VJPs.
    #[test]
    fn zero_seed_short_circuits() {
        let f = Linear::new(1.0, 1);
        let out = step_vjp(&f, tableau::dopri5(), 0.0, 0.1, &[1.0], &[0.0], &mut vec![0.0], false);
        assert_eq!(out.nvjp, 0);
        assert_eq!(out.dz, vec![0.0]);
    }

    /// Shared-stage batched step adjoint: dz, accumulated dθ and the
    /// per-sample VJP meters must be bit-identical to n scalar `step_vjp`
    /// calls — including mixed per-sample step sizes and times, parameterful
    /// dynamics, and dθ accumulation across consecutive steps.
    #[test]
    fn step_vjp_batch_bit_identical_to_scalar() {
        let f = Linear::new(-0.9, 2);
        for tab in [tableau::euler(), tableau::rk4(), tableau::heun_euler(), tableau::dopri5()] {
            let n = 3;
            let ts = [0.1f64, 0.7, 1.3];
            let hs = [0.25f64, 0.1, 0.31];
            let zs = [1.4f32, -0.6, 0.9, 0.2, -1.1, 0.5];
            let lams = [1.0f32, 0.5, -0.25, 0.8, 0.0, -1.0];

            let mut dzs = vec![0.0f32; n * 2];
            let mut dthetas = vec![0.3f32; n]; // nonzero: accumulation path
            let mut nvjps = vec![0usize; n];
            let mut scratch = StepVjpBatchScratch::new();
            let nfe = step_vjp_batch(
                &f, tab, &ts, &hs, &zs, &lams, &mut dzs, &mut dthetas, &mut nvjps, &mut scratch,
            );
            // Second step through the same scratch: dθ keeps accumulating.
            let nfe2 = step_vjp_batch(
                &f, tab, &ts, &hs, &zs, &dzs.clone(), &mut dzs, &mut dthetas, &mut nvjps,
                &mut scratch,
            );
            assert_eq!(nfe, tab.stages, "{}", tab.name);
            assert_eq!(nfe2, tab.stages);

            for i in 0..n {
                let mut dtheta = vec![0.3f32; 1];
                let out1 = step_vjp(
                    &f,
                    tab,
                    ts[i],
                    hs[i],
                    &zs[i * 2..(i + 1) * 2],
                    &lams[i * 2..(i + 1) * 2],
                    &mut dtheta,
                    false,
                );
                let out2 = step_vjp(
                    &f, tab, ts[i], hs[i], &zs[i * 2..(i + 1) * 2], &out1.dz, &mut dtheta, false,
                );
                assert_eq!(&dzs[i * 2..(i + 1) * 2], &out2.dz[..], "{} sample {i}", tab.name);
                assert_eq!(dthetas[i], dtheta[0], "{} sample {i} dθ", tab.name);
                assert_eq!(nvjps[i], out1.nvjp + out2.nvjp, "{} sample {i} nvjp", tab.name);
            }
        }
    }

    /// A sample with an all-zero cotangent must cost zero VJPs in the shared
    /// sweep while its neighbors still get full-precision results.
    #[test]
    fn step_vjp_batch_skips_dead_samples_per_stage() {
        let f = VanDerPol::new(0.2);
        let tab = tableau::dopri5();
        let ts = [0.0f64, 0.0];
        let hs = [0.2f64, 0.2];
        let zs = [1.5f32, -0.4, 1.5, -0.4];
        let lams = [0.0f32, 0.0, 1.0, -0.5]; // sample 0 dead, sample 1 live
        let mut dzs = vec![9.0f32; 4];
        let mut dthetas: Vec<f32> = vec![];
        let mut nvjps = vec![0usize; 2];
        let mut scratch = StepVjpBatchScratch::new();
        step_vjp_batch(
            &f, tab, &ts, &hs, &zs, &lams, &mut dzs, &mut dthetas, &mut nvjps, &mut scratch,
        );
        assert_eq!(nvjps[0], 0, "dead sample must be skipped stage-by-stage");
        assert_eq!(&dzs[0..2], &[0.0, 0.0]);
        let out = step_vjp(&f, tab, 0.0, 0.2, &zs[2..4], &lams[2..4], &mut vec![], false);
        assert_eq!(&dzs[2..4], &out.dz[..]);
        assert_eq!(nvjps[1], out.nvjp);
    }
}
