//! Exact discrete adjoint of one RK step — the "local backward" of the
//! paper's Algo 2.
//!
//! For a tableau `(A, b, c)` the step is
//!
//! ```text
//! u_j = z + h Σ_{l<j} a_jl k_l        k_j = f(t + c_j h, u_j)
//! y   = z + h Σ_j b_j k_j
//! ```
//!
//! Given `λ = dL/dy`, the reverse sweep computes `dL/dz`, accumulates
//! `dL/dθ`, and (for the naive method) the *explicit* `dL/dh`:
//!
//! ```text
//! k̄_j  = h b_j λ                                    (seed)
//! for j = s−1 .. 0:
//!     w_j   = k̄_j
//!     ŵ_j  = w_jᵀ ∂f/∂u |_{u_j}      (one VJP; also yields w_jᵀ ∂f/∂θ)
//!     dz   += ŵ_j ;   k̄_l += h a_jl ŵ_j  (l < j)
//! dz += λ
//! dh  = λ·Σ_j b_j k_j + Σ_j ŵ_j·Σ_{l<j} a_jl k_l    (f autonomous: no ∂f/∂t)
//! ```
//!
//! The stages are recomputed from the checkpoint (`m+1`-th evaluation in the
//! paper's cost accounting) and freed immediately — "delete local
//! computation graphs".

use crate::ode::func::OdeFunc;
use crate::ode::tableau::Tableau;
use crate::tensor;

/// Output of a step VJP.
#[derive(Debug, Clone)]
pub struct StepVjp {
    /// `dL/dz` at the step's start state.
    pub dz: Vec<f32>,
    /// Explicit `dL/dh` (0 unless requested).
    pub dh: f64,
    /// `f` evaluations spent recomputing stages.
    pub nfe: usize,
    /// VJP calls spent.
    pub nvjp: usize,
}

/// Recompute the stages of a step from `(t, h, z)`.
///
/// Returns `(us, ks)` where `us[j]` is the stage input and `ks[j]` the stage
/// derivative.
fn recompute_stages<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    z: &[f32],
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let s = tab.stages;
    let dim = z.len();
    let mut us: Vec<Vec<f32>> = Vec::with_capacity(s);
    let mut ks: Vec<Vec<f32>> = Vec::with_capacity(s);
    for j in 0..s {
        let mut u = z.to_vec();
        for (l, a) in tab.a[j].iter().enumerate() {
            if *a != 0.0 {
                tensor::axpy((h * *a) as f32, &ks[l], &mut u);
            }
        }
        let mut k = vec![0.0f32; dim];
        f.eval(t + tab.c[j] * h, &u, &mut k);
        us.push(u);
        ks.push(k);
    }
    (us, ks)
}

/// Shared reverse sweep: given per-stage seeds `k̄_j` (`bar_k`), run the
/// stage-reverse recursion. Adds the result into `dz` and `dtheta`, returns
/// the Σ_j ŵ_j · (Σ_{l<j} a_jl k_l) term of `dh` plus vjp count.
#[allow(clippy::too_many_arguments)]
fn reverse_sweep<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    us: &[Vec<f32>],
    ks: &[Vec<f32>],
    mut bar_k: Vec<Vec<f32>>,
    dz: &mut [f32],
    dtheta: &mut [f32],
    want_dh: bool,
) -> (f64, usize) {
    let s = tab.stages;
    let dim = dz.len();
    let mut wjz = vec![0.0f32; dim];
    let mut dh_inner = 0.0f64;
    let mut nvjp = 0usize;
    for j in (0..s).rev() {
        // Skip dead stages (seed exactly zero and no downstream contribution).
        if bar_k[j].iter().all(|&v| v == 0.0) {
            continue;
        }
        f.vjp(t + tab.c[j] * h, &us[j], &bar_k[j], &mut wjz, dtheta);
        nvjp += 1;
        tensor::axpy(1.0, &wjz, dz);
        for (l, a) in tab.a[j].iter().enumerate() {
            if *a != 0.0 {
                let (lo, _) = bar_k.split_at_mut(j);
                tensor::axpy((h * *a) as f32, &wjz, &mut lo[l]);
            }
        }
        if want_dh {
            // ŵ_j · (Σ_{l<j} a_jl k_l) = ŵ_j · (u_j − z)/h ; use the a-form
            // to stay exact when h is tiny.
            let mut acc = 0.0f64;
            for (l, a) in tab.a[j].iter().enumerate() {
                if *a != 0.0 {
                    acc += *a * tensor::dot(&wjz, &ks[l]);
                }
            }
            dh_inner += acc;
        }
    }
    (dh_inner, nvjp)
}

/// Exact VJP of `ψ_h(t, z)` (see module docs). `dtheta` is accumulated into.
pub fn step_vjp<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    z: &[f32],
    lam: &[f32],
    dtheta: &mut [f32],
    want_dh: bool,
) -> StepVjp {
    let s = tab.stages;
    let dim = z.len();
    let (us, ks) = recompute_stages(f, tab, t, h, z);

    // Seed: k̄_j = h b_j λ.
    let bar_k: Vec<Vec<f32>> = (0..s)
        .map(|j| {
            if tab.b[j] == 0.0 {
                vec![0.0f32; dim]
            } else {
                lam.iter().map(|&l| (h * tab.b[j]) as f32 * l).collect()
            }
        })
        .collect();

    let mut dz = vec![0.0f32; dim];
    let (dh_inner, nvjp) =
        reverse_sweep(f, tab, t, h, &us, &ks, bar_k, &mut dz, dtheta, want_dh);

    // Direct z path of y = z + ...
    tensor::axpy(1.0, lam, &mut dz);

    let dh = if want_dh {
        // λ · Σ_j b_j k_j
        let mut d = 0.0f64;
        for j in 0..s {
            if tab.b[j] != 0.0 {
                d += tab.b[j] * tensor::dot(lam, &ks[j]);
            }
        }
        d + dh_inner
    } else {
        0.0
    };

    StepVjp { dz, dh, nfe: s, nvjp }
}

/// VJP of the *error norm* of a step attempt — the quantity the naive method
/// backpropagates through the step-size controller (paper Sec 3.3).
///
/// `err = sqrt(mean_i (ev_i / sc_i)²)` with `ev = h Σ_j e_j k_j` and
/// `sc_i = atol + rtol·|z_i|`. Both paths are differentiated: through the
/// error vector (stage reverse sweep) and through the tolerance scale
/// (`∂err/∂z_i ⊇ −ev_i²·rtol·sign(z_i)/(sc_i³·N·err)`) — the latter is what
/// makes the error norm nearly scale-invariant for homogeneous dynamics, so
/// dropping it would bias the naive method's h-chain.
///
/// Scales everything by the upstream gradient `gbar = dL/derr`; adds into
/// `dz_accum`/`dtheta`, returns `dL/dh` (explicit) plus costs.
#[allow(clippy::too_many_arguments)]
pub fn err_norm_vjp<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    z: &[f32],
    atol: f64,
    rtol: f64,
    gbar: f64,
    dz_accum: &mut [f32],
    dtheta: &mut [f32],
) -> (f64, usize, usize) {
    let e = tab
        .b_err
        .expect("err_norm_vjp requires an adaptive tableau");
    let s = tab.stages;
    let dim = z.len();
    let (us, ks) = recompute_stages(f, tab, t, h, z);

    // Recompute the error vector (the scale uses the start state only —
    // matching rk_step — so `err` has no z_next dependence).
    let mut ev = vec![0.0f32; dim];
    for (c, k) in e.iter().zip(&ks) {
        if *c != 0.0 {
            tensor::axpy((h * *c) as f32, k, &mut ev);
        }
    }
    let err = tensor::wrms_norm(&ev, z, z, atol, rtol);
    if err <= 0.0 || !err.is_finite() {
        return (0.0, s, 0);
    }

    // d err / d ev_i = ev_i / (sc_i² · N · err).
    let n = dim as f64;
    let w_ev: Vec<f32> = (0..dim)
        .map(|i| {
            let sc = atol + rtol * z[i].abs() as f64;
            ((ev[i] as f64 / (sc * sc)) / (n * err) * gbar) as f32
        })
        .collect();

    // Seed k̄_j = h e_j w_ev.
    let bar_k: Vec<Vec<f32>> = (0..s)
        .map(|j| {
            if e[j] == 0.0 {
                vec![0.0f32; dim]
            } else {
                w_ev.iter().map(|&l| (h * e[j]) as f32 * l).collect()
            }
        })
        .collect();

    let mut dz = vec![0.0f32; dim];
    let (dh_inner, nvjp) = reverse_sweep(f, tab, t, h, &us, &ks, bar_k, &mut dz, dtheta, true);
    tensor::axpy(1.0, &dz, dz_accum);

    // Direct tolerance-scale path: ∂err/∂z_i = −ev_i²·rtol·sign(z_i)/(sc_i³·N·err).
    if rtol != 0.0 {
        for i in 0..dim {
            if z[i] == 0.0 {
                continue; // sub-gradient of |z| at 0
            }
            let sc = atol + rtol * z[i].abs() as f64;
            let evi = ev[i] as f64;
            let d = -(evi * evi) * rtol * z[i].signum() as f64 / (sc * sc * sc * n * err);
            dz_accum[i] += (gbar * d) as f32;
        }
    }

    // Explicit h path of ev = h Σ e_j k_j.
    let mut dh = dh_inner;
    for j in 0..s {
        if e[j] != 0.0 {
            dh += e[j] * tensor::dot(&w_ev, &ks[j]);
        }
    }
    (dh, s, nvjp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::step::{rk_step, StepScratch};
    use crate::ode::tableau;

    /// For dz/dt = kz one RK step is linear: y = R(kh) z with a rational
    /// stability polynomial. The VJP w.r.t. z must be R(kh) · λ.
    #[test]
    fn linear_step_vjp_exact() {
        let k = -0.8f64;
        let f = Linear::new(k as f32, 1);
        for tab in [tableau::euler(), tableau::rk4(), tableau::dopri5()] {
            let h = 0.3f64;
            // Stability polynomial R = Σ_i (kh)^i / i! truncated at order.
            // Compute R numerically by stepping z=1.
            let mut y = [0.0f32];
            let mut scratch = StepScratch::new();
            rk_step(&f, tab, 0.0, h, &[1.0], None, 1e-9, 1e-9, &mut y, None, &mut scratch);
            let r = y[0] as f64;
            let lam = [2.5f32];
            let mut dtheta = vec![0.0f32; 1];
            let out = step_vjp(&f, tab, 0.0, h, &[1.0], &lam, &mut dtheta, false);
            assert!(
                (out.dz[0] as f64 - r * 2.5).abs() < 1e-5,
                "{}: dz {} vs R*lam {}",
                tab.name,
                out.dz[0],
                r * 2.5
            );
        }
    }

    /// Finite-difference check of dz, dθ, dh on a nonlinear system.
    #[test]
    fn step_vjp_matches_finite_difference() {
        let f = VanDerPol::new(0.15);
        let tab = tableau::dopri5();
        let t = 0.4;
        let h = 0.21;
        let z = [1.7f32, -0.3];
        let lam = [0.8f32, -1.2];
        let mut dtheta: Vec<f32> = vec![];
        let out = step_vjp(&f, tab, t, h, &z, &lam, &mut dtheta, true);

        let step = |zz: &[f32], hh: f64| -> f64 {
            let mut y = [0.0f32; 2];
            let mut s = StepScratch::new();
            rk_step(&f, tab, t, hh, zz, None, 1e-9, 1e-9, &mut y, None, &mut s);
            lam.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum()
        };

        // dz
        for i in 0..2 {
            let eps = 1e-3f32;
            let mut zp = z;
            zp[i] += eps;
            let mut zm = z;
            zm[i] -= eps;
            let fd = (step(&zp, h) - step(&zm, h)) / (2.0 * eps as f64);
            assert!(
                (out.dz[i] as f64 - fd).abs() < 2e-3 * fd.abs().max(1.0),
                "dz[{i}]: {} vs fd {}",
                out.dz[i],
                fd
            );
        }
        // dh (eps sized for f32 state noise: curvature error O(eps²) vs
        // roundoff O(1e-7/eps)).
        let eps = 1e-3;
        let fd_h = (step(&z, h + eps) - step(&z, h - eps)) / (2.0 * eps);
        assert!(
            (out.dh - fd_h).abs() < 5e-3 * fd_h.abs().max(1.0),
            "dh: {} vs fd {}",
            out.dh,
            fd_h
        );
    }

    /// dθ check on the linear system where dψ/dk is analytic-ish via FD.
    #[test]
    fn step_vjp_dtheta_matches_fd() {
        let tab = tableau::rk23();
        let h = 0.25f64;
        let z = [1.4f32, -0.6, 0.9];
        let lam = [1.0f32, 0.5, -0.25];
        let f = Linear::new(-0.9, 3);
        let mut dtheta = vec![0.0f32; 1];
        step_vjp(&f, tab, 0.0, h, &z, &lam, &mut dtheta, false);

        let loss_with_k = |k: f32| -> f64 {
            let fk = Linear::new(k, 3);
            let mut y = [0.0f32; 3];
            let mut s = StepScratch::new();
            rk_step(&fk, tab, 0.0, h, &z, None, 1e-9, 1e-9, &mut y, None, &mut s);
            lam.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let eps = 1e-3f32;
        let fd = (loss_with_k(-0.9 + eps) - loss_with_k(-0.9 - eps)) / (2.0 * eps as f64);
        assert!(
            (dtheta[0] as f64 - fd).abs() < 2e-3 * fd.abs().max(1.0),
            "dtheta {} vs fd {}",
            dtheta[0],
            fd
        );
    }

    /// dtheta accumulates across calls.
    #[test]
    fn dtheta_accumulates() {
        let f = Linear::new(0.5, 2);
        let tab = tableau::heun_euler();
        let mut dtheta = vec![0.0f32; 1];
        let z = [1.0f32, 2.0];
        let lam = [1.0f32, 1.0];
        step_vjp(&f, tab, 0.0, 0.1, &z, &lam, &mut dtheta, false);
        let first = dtheta[0];
        step_vjp(&f, tab, 0.0, 0.1, &z, &lam, &mut dtheta, false);
        assert!((dtheta[0] - 2.0 * first).abs() < 1e-6);
    }

    /// err_norm_vjp: finite-difference check of d err/d h and d err/d z.
    #[test]
    fn err_vjp_matches_finite_difference() {
        let f = VanDerPol::new(0.15);
        let tab = tableau::dopri5();
        let (t, h) = (0.0, 0.4);
        // Keep both components away from 0: |z| has a kink there and the
        // central FD of the scale path would be biased.
        let z = [2.0f32, 0.5];
        let (atol, rtol) = (1e-6, 1e-4);

        let err_of = |zz: &[f32], hh: f64| -> f64 {
            let mut y = [0.0f32; 2];
            let mut s = StepScratch::new();
            rk_step(&f, tab, t, hh, zz, None, atol, rtol, &mut y, None, &mut s).err_norm
        };

        let mut dz = vec![0.0f32; 2];
        let mut dtheta: Vec<f32> = vec![];
        let (dh, _, _) = err_norm_vjp(&f, tab, t, h, &z, atol, rtol, 1.0, &mut dz, &mut dtheta);

        let eps = 1e-4;
        let fd_h = (err_of(&z, h + eps) - err_of(&z, h - eps)) / (2.0 * eps);
        assert!(
            (dh - fd_h).abs() < 1e-2 * fd_h.abs().max(1e-9),
            "dh {} vs fd {}",
            dh,
            fd_h
        );

        for i in 0..2 {
            let eps = 1e-3f32;
            let mut zp = z;
            zp[i] += eps;
            let mut zm = z;
            zm[i] -= eps;
            let fd = (err_of(&zp, h) - err_of(&zm, h)) / (2.0 * eps as f64);
            assert!(
                (dz[i] as f64 - fd).abs() < 0.02 * fd.abs().max(1e-9),
                "dz[{i}] {} vs fd {}",
                dz[i],
                fd
            );
        }
    }

    /// Gradient seeds that are zero must cost zero VJPs.
    #[test]
    fn zero_seed_short_circuits() {
        let f = Linear::new(1.0, 1);
        let out = step_vjp(&f, tableau::dopri5(), 0.0, 0.1, &[1.0], &[0.0], &mut vec![0.0], false);
        assert_eq!(out.nvjp, 0);
        assert_eq!(out.dz, vec![0.0]);
    }
}
