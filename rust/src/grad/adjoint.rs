//! The **continuous adjoint method** (Pontryagin 1962; Chen et al. 2018) —
//! the paper's reverse-inaccurate baseline (Sec 3.2, Theorem 3.2).
//!
//! Memory is `O(N_f)`: the forward trajectory is *forgotten*; only the
//! boundary condition `z(T)` is kept. The backward pass solves the augmented
//! IVP from `T` to `0` with its **own** adaptive discretization:
//!
//! ```text
//! y = [ z̄ , a , g ]                  y(T) = [ z(T), dL/dz(T), 0 ]
//! z̄' = f(t, z̄)
//! a'  = −aᵀ ∂f/∂z̄                    (one VJP per stage)
//! g'  = −aᵀ ∂f/∂θ
//! ```
//!
//! so that `a(0) = dL/dz(0)` and `g(0) = dL/dθ`. Because `z̄(t)` is solved
//! numerically rather than remembered, `z̄(t) ≠ z(t)` (paper Fig 3/4) and the
//! gradient inherits the reverse-time error `e_k` of Theorem 3.2.

use super::{CostMeter, GradResult};
use crate::ode::func::OdeFunc;
use crate::ode::integrate::{integrate, IntegrateOpts, Trajectory};
use crate::ode::tableau::Tableau;

/// Options for the reverse augmented solve.
#[derive(Debug, Clone)]
pub struct AdjointOpts {
    pub rtol: f64,
    pub atol: f64,
    pub max_steps: usize,
    /// Fixed step for non-adaptive reverse solves.
    pub fixed_h: Option<f64>,
}

impl AdjointOpts {
    /// Mirror the forward tolerances, as torchdiffeq does by default.
    pub fn from_integrate(opts: &IntegrateOpts) -> Self {
        AdjointOpts {
            rtol: opts.rtol,
            atol: opts.atol,
            max_steps: opts.max_steps,
            fixed_h: opts.fixed_h,
        }
    }
}

/// The augmented reverse dynamics over `[z̄, a, g]`.
struct Augmented<'a, F: OdeFunc + ?Sized> {
    f: &'a F,
    dim: usize,
    n_params: usize,
}

impl<F: OdeFunc + ?Sized> OdeFunc for Augmented<'_, F> {
    fn dim(&self) -> usize {
        2 * self.dim + self.n_params
    }

    fn eval(&self, t: f64, y: &[f32], dy: &mut [f32]) {
        let d = self.dim;
        let (z, rest) = y.split_at(d);
        let (a, _g) = rest.split_at(d);
        {
            let (dz, drest) = dy.split_at_mut(d);
            self.f.eval(t, z, dz);
            let (da, dg) = drest.split_at_mut(d);
            // a' = −aᵀ ∂f/∂z ; g' = −aᵀ ∂f/∂θ.
            let mut wjp = vec![0.0f32; self.n_params];
            self.f.vjp(t, z, a, da, &mut wjp);
            for v in da.iter_mut() {
                *v = -*v;
            }
            for (dgi, w) in dg.iter_mut().zip(&wjp) {
                *dgi = -w;
            }
        }
    }

    fn vjp(&self, _t: f64, _z: &[f32], _w: &[f32], _wjz: &mut [f32], _wjp: &mut [f32]) {
        unreachable!("augmented dynamics is never differentiated");
    }
}

/// Run the continuous-adjoint backward pass.
///
/// Only `traj`'s endpoints are consulted (the method forgets the interior —
/// that is the point). Returns gradients plus the cost meter; `n_reverse_steps`
/// is the paper's `N_r`.
pub fn adjoint_backward<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &Trajectory,
    lam_t1: &[f32],
    opts: &AdjointOpts,
) -> anyhow::Result<GradResult> {
    let d = f.dim();
    let p = f.n_params();
    assert_eq!(lam_t1.len(), d);
    let t0 = traj.ts[0];
    let t1 = *traj.ts.last().unwrap();

    let aug = Augmented { f, dim: d, n_params: p };
    let mut y1 = vec![0.0f32; 2 * d + p];
    y1[..d].copy_from_slice(traj.last().expect("adjoint needs a non-empty trajectory"));
    y1[d..2 * d].copy_from_slice(lam_t1);

    let iopts = IntegrateOpts {
        rtol: opts.rtol,
        atol: opts.atol,
        max_steps: opts.max_steps,
        fixed_h: opts.fixed_h,
        ..Default::default()
    };
    let rev = integrate(&aug, t1, t0, &y1, tab, &iopts)?;

    let y0 = rev.last().expect("reverse solve always has a final state");
    let meter = CostMeter {
        nfe_forward: traj.nfe,
        // Each augmented eval costs one f eval + one VJP.
        nfe_backward: rev.nfe,
        vjp_calls: rev.nfe,
        // O(N_f): one augmented state; no trajectory checkpoints kept —
        // and therefore nothing to replay (`..Default` zeroes nfe_replay).
        checkpoint_bytes: (2 * d + p) * std::mem::size_of::<f32>(),
        graph_depth: rev.nfe,
        n_steps: traj.len(),
        n_rejected: traj.n_rejected,
        n_reverse_steps: rev.len(),
        ..Default::default()
    };

    Ok(GradResult {
        dl_dz0: y0[d..2 * d].to_vec(),
        dl_dtheta: y0[2 * d..].to_vec(),
        meter,
    })
}

/// Reverse-solve *only the state* from `z(T)` back to `t0` — the paper's
/// Fig 4/5 reconstruction experiment (how far does `z̄(0)` land from `z(0)`?).
pub fn reverse_state_only<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t0: f64,
    t1: f64,
    z_t1: &[f32],
    opts: &IntegrateOpts,
) -> anyhow::Result<Trajectory> {
    integrate(f, t1, t0, z_t1, tab, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::{integrate, tableau, IntegrateOpts};

    /// On the linear toy problem the adjoint gradient converges to the
    /// analytic one as tolerances tighten.
    #[test]
    fn toy_gradient_converges_with_tolerance() {
        let f = Linear::new(-0.5, 1);
        let tab = tableau::dopri5();
        let exact = f.exact_dl_dz0(1.0, 4.0);
        let mut errs = Vec::new();
        for tol in [1e-4, 1e-7] {
            let opts = IntegrateOpts::with_tol(tol, tol * 1e-2);
            let traj = integrate(&f, 0.0, 4.0, &[1.0], tab, &opts).unwrap();
            let zt = traj.last().unwrap()[0];
            let g = adjoint_backward(
                &f,
                tab,
                &traj,
                &[2.0 * zt],
                &AdjointOpts::from_integrate(&opts),
            )
            .unwrap();
            errs.push(((g.dl_dz0[0] as f64 - exact) / exact).abs());
        }
        assert!(errs[1] < errs[0], "tighter tol must reduce error: {errs:?}");
        assert!(errs[1] < 1e-3, "tight-tol error too large: {errs:?}");
    }

    /// Parameter gradient against the analytic dL/dk.
    #[test]
    fn toy_parameter_gradient() {
        let f = Linear::new(-0.5, 1);
        let tab = tableau::dopri5();
        let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
        let traj = integrate(&f, 0.0, 3.0, &[1.0], tab, &opts).unwrap();
        let zt = traj.last().unwrap()[0];
        let g = adjoint_backward(
            &f,
            tab,
            &traj,
            &[2.0 * zt],
            &AdjointOpts::from_integrate(&opts),
        )
        .unwrap();
        let exact = f.exact_dl_dk(1.0, 3.0);
        let rel = ((g.dl_dtheta[0] as f64 - exact) / exact).abs();
        assert!(rel < 1e-3, "dk {} vs {} rel {rel}", g.dl_dtheta[0], exact);
    }

    /// The adjoint's accounted memory is O(state), far below ACA's
    /// checkpoints on a long solve (Table 1 memory column).
    #[test]
    fn memory_is_constant_in_steps() {
        let f = VanDerPol::new(0.15);
        let tab = tableau::dopri5();
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let traj = integrate(&f, 0.0, 20.0, &[2.0, 0.0], tab, &opts).unwrap();
        let g = adjoint_backward(&f, tab, &traj, &[1.0, 0.0], &AdjointOpts::from_integrate(&opts))
            .unwrap();
        assert!(g.meter.checkpoint_bytes < traj.checkpoint_bytes());
        assert!(g.meter.n_reverse_steps > 0);
    }

    /// Reverse-state reconstruction degrades at loose tolerance (Fig 4).
    #[test]
    fn reverse_reconstruction_error_grows_with_tolerance() {
        let f = VanDerPol::new(0.15);
        let tab = tableau::dopri5();
        let z0 = [2.0f32, 0.0];
        let mut errs = Vec::new();
        for tol in [1e-3, 1e-8] {
            let opts = IntegrateOpts::with_tol(tol, tol * 1e-2);
            let fwd = integrate(&f, 0.0, 25.0, &z0, tab, &opts).unwrap();
            let rev =
                reverse_state_only(&f, tab, 0.0, 25.0, fwd.last().unwrap(), &opts).unwrap();
            errs.push(crate::tensor::max_abs_diff(rev.last().unwrap(), &z0) as f64);
        }
        // (f32 state precision floors the tight-tol error, so only a
        // modest separation is guaranteed.)
        assert!(
            errs[0] > errs[1] * 2.0,
            "loose-tol reverse error should dominate: {errs:?}"
        );
    }
}
