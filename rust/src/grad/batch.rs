//! Batched ACA backward pass: replay each sample's saved `(t_i, h_i, z_i)`
//! checkpoints straight out of the [`BatchTrajectory`]'s shared arena and
//! run the exact discrete step adjoint — per-sample results are
//! bit-identical to [`aca_backward`](super::aca_backward) over the
//! equivalent per-sample [`Trajectory`](crate::ode::Trajectory) (asserted by
//! `rust/tests/proptests.rs`).
//!
//! The naive and continuous-adjoint methods keep their per-sample
//! formulations (the naive h-chain and the reverse augmented solve have no
//! shared structure across samples); [`backward_batch`] routes them through
//! [`BatchTrajectory::to_trajectory`].

use super::step_vjp::step_vjp;
use super::{CostMeter, GradResult, Method};
use crate::ode::batch::BatchTrajectory;
use crate::ode::func::OdeFunc;
use crate::ode::integrate::IntegrateOpts;
use crate::ode::tableau::Tableau;

/// Run the ACA backward pass for every sample of a batched trajectory.
///
/// * `lam_t1` — `dL/dz(T)` for all samples, row-major `[B × D]`.
///
/// Returns one [`GradResult`] per sample, with per-sample exact cost meters
/// (forward NFE, checkpoint bytes, rejected-trial counts).
pub fn aca_backward_batch<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &BatchTrajectory,
    lam_t1: &[f32],
) -> Vec<GradResult> {
    let d = f.dim();
    assert_eq!(d, traj.dim, "dynamics dim != trajectory dim");
    assert_eq!(lam_t1.len(), traj.batch * d, "lam length != B × D");

    (0..traj.batch)
        .map(|i| {
            let tr = &traj.tracks[i];
            let n = tr.steps();
            let mut lam = lam_t1[i * d..(i + 1) * d].to_vec();
            let mut dtheta = vec![0.0f32; f.n_params()];
            let mut meter = CostMeter {
                nfe_forward: tr.nfe,
                checkpoint_bytes: traj.checkpoint_bytes(i),
                n_steps: n,
                n_rejected: tr.n_rejected,
                ..Default::default()
            };
            // Reverse sweep over the sample's saved discretization points
            // (paper Algo 2), reading states from the shared arena.
            for k in (0..n).rev() {
                let out =
                    step_vjp(f, tab, tr.ts[k], tr.hs[k], traj.z(i, k), &lam, &mut dtheta, false);
                lam = out.dz;
                meter.nfe_backward += out.nfe;
                meter.vjp_calls += out.nvjp;
                meter.graph_depth += out.nvjp;
            }
            GradResult { dl_dz0: lam, dl_dtheta: dtheta, meter }
        })
        .collect()
}

/// Batched counterpart of [`super::backward`]: run the backward pass of
/// `method` for every sample of a batched trajectory.
pub fn backward_batch<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &BatchTrajectory,
    lam_t1: &[f32],
    method: Method,
    opts: &IntegrateOpts,
) -> anyhow::Result<Vec<GradResult>> {
    let d = f.dim();
    match method {
        Method::Aca => Ok(aca_backward_batch(f, tab, traj, lam_t1)),
        Method::Naive => Ok((0..traj.batch)
            .map(|i| {
                super::naive_backward(
                    f,
                    tab,
                    &traj.to_trajectory(i),
                    &lam_t1[i * d..(i + 1) * d],
                    opts,
                )
            })
            .collect()),
        Method::Adjoint => (0..traj.batch)
            .map(|i| {
                super::adjoint_backward(
                    f,
                    tab,
                    &traj.to_trajectory(i),
                    &lam_t1[i * d..(i + 1) * d],
                    &super::AdjointOpts::from_integrate(opts),
                )
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::aca_backward;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::{integrate, integrate_batch, tableau, IntegrateOpts};

    #[test]
    fn matches_per_sample_aca_bitwise() {
        let f = VanDerPol::new(0.4);
        let z0 = [2.0f32, 0.0, -1.2, 0.7, 0.4, 1.1];
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let tab = tableau::dopri5();
        let bt = integrate_batch(&f, 0.0, 2.5, &z0, tab, &opts).unwrap();
        let lam = [1.0f32, -0.5, 0.3, 0.9, -1.0, 0.2];
        let gb = aca_backward_batch(&f, tab, &bt, &lam);
        for i in 0..3 {
            let traj = integrate(&f, 0.0, 2.5, &z0[i * 2..(i + 1) * 2], tab, &opts).unwrap();
            let ga = aca_backward(&f, tab, &traj, &lam[i * 2..(i + 1) * 2]);
            assert_eq!(gb[i].dl_dz0, ga.dl_dz0, "sample {i}");
            assert_eq!(gb[i].meter.nfe_backward, ga.meter.nfe_backward);
            assert_eq!(gb[i].meter.vjp_calls, ga.meter.vjp_calls);
            assert_eq!(gb[i].meter.checkpoint_bytes, ga.meter.checkpoint_bytes);
        }
    }

    /// The paper's toy problem per sample: dL/dz0 = 2 z0 exp(2kT).
    #[test]
    fn toy_gradient_accuracy_per_sample() {
        let k = -0.5f32;
        let f = Linear::new(k, 1);
        let z0 = [1.0f32, 2.0, -1.5];
        let t_end = 3.0;
        let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
        let bt = integrate_batch(&f, 0.0, t_end, &z0, tableau::dopri5(), &opts).unwrap();
        let lam: Vec<f32> = (0..3).map(|i| 2.0 * bt.last(i)[0]).collect();
        let g = aca_backward_batch(&f, tableau::dopri5(), &bt, &lam);
        for i in 0..3 {
            let exact = f.exact_dl_dz0(z0[i], t_end);
            let rel = ((g[i].dl_dz0[0] as f64 - exact) / exact).abs();
            assert!(rel < 1e-4, "sample {i}: {} vs {exact} (rel {rel})", g[i].dl_dz0[0]);
        }
    }

    #[test]
    fn backward_batch_dispatches_all_methods() {
        let f = Linear::new(-0.3, 2);
        let z0 = [1.0f32, -1.0, 0.5, 2.0];
        let opts = IntegrateOpts { record_trials: true, ..IntegrateOpts::with_tol(1e-6, 1e-8) };
        let tab = tableau::dopri5();
        let bt = integrate_batch(&f, 0.0, 2.0, &z0, tab, &opts).unwrap();
        let lam = [1.0f32, 0.0, 0.0, 1.0];
        for method in Method::all() {
            let gs = backward_batch(&f, tab, &bt, &lam, method, &opts).unwrap();
            assert_eq!(gs.len(), 2, "{}", method.name());
            let exact = (-0.3f64 * 2.0).exp(); // dz(T)/dz0 = e^{kT} per component
            for (i, g) in gs.iter().enumerate() {
                let nz: f64 = g.dl_dz0.iter().map(|v| *v as f64).sum();
                assert!(
                    (nz - exact).abs() < 0.05 * exact,
                    "{} sample {i}: {nz} vs {exact}",
                    method.name()
                );
            }
        }
    }
}
