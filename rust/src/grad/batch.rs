//! Batched ACA backward pass with **shared stage recomputation**: replay
//! each sample's saved `(t_i, h_i, z_i)` checkpoints straight out of the
//! [`BatchTrajectory`]'s shared arena and run the exact discrete step
//! adjoint for all samples sharing a reverse round at once — one
//! [`OdeFunc::eval_batch`]/[`OdeFunc::vjp_batch`] sweep per stage per round
//! ([`super::step_vjp_batch`]) instead of one scalar call per sample,
//! mirroring the forward engine's stage sweeps in `ode/batch.rs`.
//!
//! Samples have different step counts, so the loop keeps an **active set**
//! symmetric to the forward loop's: each sample starts at its own last step
//! and retires from the shared sweep when its reverse index underflows.
//! Nothing here assumes a shared span: every reverse round reads per-sample
//! `(t, h, z)` straight off each sample's own track, so trajectories
//! recorded by [`integrate_batch_spans`](crate::ode::integrate_batch_spans)
//! — mixed endpoints, even mixed directions — replay exactly like
//! shared-span ones, each sample's meters keyed off its own step count.
//! Per-sample results — `dL/dz0`, `dL/dθ`, and every meter — are
//! bit-identical to [`aca_backward`](super::aca_backward) over the
//! equivalent per-sample [`Trajectory`](crate::ode::Trajectory) (asserted by
//! `rust/tests/proptests.rs`).
//!
//! The naive and continuous-adjoint methods keep their per-sample
//! formulations (the naive h-chain and the reverse augmented solve have no
//! shared structure across samples); [`backward_batch`] routes them through
//! [`BatchTrajectory::to_trajectory`].

use super::step_vjp::{step_vjp_batch, StepVjpBatchScratch};
use super::{CostMeter, GradResult, Method};
use crate::ckpt::SegmentCache;
use crate::ode::batch::BatchTrajectory;
use crate::ode::func::OdeFunc;
use crate::ode::integrate::IntegrateOpts;
use crate::ode::tableau::Tableau;

/// Run the ACA backward pass for every sample of a batched trajectory,
/// sharing stage recomputation across samples.
///
/// * `lam_t1` — `dL/dz(T)` for all samples, row-major `[B × D]`.
///
/// Checkpoints are fetched per sample through a [`SegmentCache`] over the
/// shared arena: a dense store hands anchors out directly (bit-for-bit the
/// old behavior); a thinned store ([`crate::ckpt`]) replays each dropped
/// state from its nearest anchor once per segment — the reverse rounds walk
/// each sample's indices strictly downward, so every segment replays
/// exactly once and the amortized overhead is one extra forward step per
/// dropped state, metered into [`CostMeter::nfe_replay`].
///
/// Returns one [`GradResult`] per sample, with per-sample exact cost meters
/// (forward NFE, checkpoint bytes, rejected-trial counts).
pub fn aca_backward_batch<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &BatchTrajectory,
    lam_t1: &[f32],
) -> Vec<GradResult> {
    let d = f.dim();
    let p = f.n_params();
    assert_eq!(d, traj.dim, "dynamics dim != trajectory dim");
    assert_eq!(lam_t1.len(), traj.batch * d, "lam length != B × D");
    let b = traj.batch;

    // Per-sample running state, indexed by sample id.
    let mut lams = lam_t1.to_vec();
    let mut dthetas = vec![0.0f32; b * p];
    let mut nfe_back = vec![0usize; b];
    let mut nvjp_tot = vec![0usize; b];
    // Reverse cursor: steps left to process; the sample retires at 0.
    let mut rem: Vec<usize> = traj.tracks.iter().map(|t| t.steps()).collect();

    // Round scratch, packed in active order (slot `a` of a round buffer is
    // the `a`-th live sample) — no allocation inside the loop, same
    // discipline as the forward loop.
    let mut active: Vec<usize> = (0..b).filter(|&i| rem[i] > 0).collect();
    let mut next_active: Vec<usize> = Vec::with_capacity(b);
    let mut ts_p = vec![0.0f64; b];
    let mut hs_p = vec![0.0f64; b];
    let mut zs_p = vec![0.0f32; b * d];
    let mut lam_p = vec![0.0f32; b * d];
    let mut dz_p = vec![0.0f32; b * d];
    let mut dth_p = vec![0.0f32; b * p];
    let mut nv_p = vec![0usize; b];
    let mut scratch = StepVjpBatchScratch::new();
    // One segment cache per sample: holds at most one inter-anchor segment
    // (≤ stride × D floats) — the transient memory of the classic
    // checkpoint/recompute trade. Dense stores never touch it.
    let mut caches: Vec<SegmentCache> = (0..b).map(|_| SegmentCache::new()).collect();

    // Reverse sweep over the saved discretization points (paper Algo 2),
    // vectorized over samples: every round runs one shared-stage step
    // adjoint over all samples whose reverse index is still in range.
    // nodal-lint: hot
    while !active.is_empty() {
        let na = active.len();
        crate::obs::hot_count(crate::obs::CTR_REV_ROUNDS, 1);
        for (a, &i) in active.iter().enumerate() {
            let k = rem[i] - 1;
            let tr = &traj.tracks[i];
            ts_p[a] = tr.ts[k];
            hs_p[a] = tr.hs[k];
            let z_k = caches[i].state(f, tab, &tr.ts, &tr.hs, traj.sample_store(i), k);
            zs_p[a * d..(a + 1) * d].copy_from_slice(z_k);
            lam_p[a * d..(a + 1) * d].copy_from_slice(&lams[i * d..(i + 1) * d]);
            // Gather the running dθ so the shared sweep accumulates straight
            // onto it (the scatter below copies it back bit-for-bit).
            dth_p[a * p..(a + 1) * p].copy_from_slice(&dthetas[i * p..(i + 1) * p]);
            nv_p[a] = 0;
        }
        // One `eval_batch` + `vjp_batch` dispatch per stage inside the
        // shared-stage step adjoint.
        crate::obs::hot_count(crate::obs::CTR_REV_SWEEPS, tab.stages as u64);
        let nfe_each = step_vjp_batch(
            f,
            tab,
            &ts_p[..na],
            &hs_p[..na],
            &zs_p[..na * d],
            &lam_p[..na * d],
            &mut dz_p[..na * d],
            &mut dth_p[..na * p],
            &mut nv_p[..na],
            &mut scratch,
        );
        next_active.clear();
        for (a, &i) in active.iter().enumerate() {
            lams[i * d..(i + 1) * d].copy_from_slice(&dz_p[a * d..(a + 1) * d]);
            dthetas[i * p..(i + 1) * p].copy_from_slice(&dth_p[a * p..(a + 1) * p]);
            nfe_back[i] += nfe_each;
            nvjp_tot[i] += nv_p[a];
            rem[i] -= 1;
            if rem[i] > 0 {
                next_active.push(i);
            }
        }
        std::mem::swap(&mut active, &mut next_active);
    }

    (0..b)
        .map(|i| {
            let tr = &traj.tracks[i];
            GradResult {
                dl_dz0: lams[i * d..(i + 1) * d].to_vec(),
                dl_dtheta: dthetas[i * p..(i + 1) * p].to_vec(),
                meter: CostMeter {
                    nfe_forward: tr.nfe,
                    nfe_backward: nfe_back[i],
                    nfe_replay: caches[i].nfe_replay,
                    replay_peak_bytes: caches[i].peak_bytes(),
                    vjp_calls: nvjp_tot[i],
                    // Depth: one chained VJP sweep per accepted step.
                    graph_depth: nvjp_tot[i],
                    checkpoint_bytes: traj.checkpoint_bytes(i),
                    n_steps: tr.steps(),
                    n_rejected: tr.n_rejected,
                    ..Default::default()
                },
            }
        })
        .collect()
}

/// Batched counterpart of [`super::backward`]: run the backward pass of
/// `method` for every sample of a batched trajectory.
pub fn backward_batch<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &BatchTrajectory,
    lam_t1: &[f32],
    method: Method,
    opts: &IntegrateOpts,
) -> anyhow::Result<Vec<GradResult>> {
    let d = f.dim();
    match method {
        Method::Aca => Ok(aca_backward_batch(f, tab, traj, lam_t1)),
        Method::Naive => Ok((0..traj.batch)
            .map(|i| {
                super::naive_backward(
                    f,
                    tab,
                    &traj.to_trajectory(i),
                    &lam_t1[i * d..(i + 1) * d],
                    opts,
                )
            })
            .collect()),
        Method::Adjoint => (0..traj.batch)
            .map(|i| {
                super::adjoint_backward(
                    f,
                    tab,
                    &traj.to_trajectory(i),
                    &lam_t1[i * d..(i + 1) * d],
                    &super::AdjointOpts::from_integrate(opts),
                )
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::aca_backward;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::{integrate, integrate_batch, tableau, IntegrateOpts};
    use std::cell::Cell;

    /// Counts batched *dispatches* (not per-sample work) — the quantity the
    /// shared-stage sweep is supposed to collapse.
    struct DispatchCounting<F> {
        inner: F,
        eval_batch_calls: Cell<usize>,
        vjp_batch_calls: Cell<usize>,
        scalar_vjp_calls: Cell<usize>,
    }
    impl<F> DispatchCounting<F> {
        fn new(inner: F) -> Self {
            DispatchCounting {
                inner,
                eval_batch_calls: Cell::new(0),
                vjp_batch_calls: Cell::new(0),
                scalar_vjp_calls: Cell::new(0),
            }
        }
    }
    impl<F: OdeFunc> OdeFunc for DispatchCounting<F> {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn n_params(&self) -> usize {
            self.inner.n_params()
        }
        fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
            self.inner.eval(t, z, dz)
        }
        fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
            self.eval_batch_calls.set(self.eval_batch_calls.get() + 1);
            self.inner.eval_batch(ts, zs, dzs)
        }
        fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
            self.scalar_vjp_calls.set(self.scalar_vjp_calls.get() + 1);
            self.inner.vjp(t, z, w, wjz, wjp)
        }
        fn vjp_batch(
            &self,
            ts: &[f64],
            zs: &[f32],
            ws: &[f32],
            wjzs: &mut [f32],
            wjps: &mut [f32],
        ) {
            self.vjp_batch_calls.set(self.vjp_batch_calls.get() + 1);
            self.inner.vjp_batch(ts, zs, ws, wjzs, wjps)
        }
        fn params(&self) -> &[f32] {
            self.inner.params()
        }
    }

    /// The shared-stage sweep must issue one `eval_batch`/`vjp_batch`
    /// dispatch per stage per reverse round — not one scalar `vjp` per
    /// sample per stage (the pre-shared-stage behavior).
    #[test]
    fn shared_stage_dispatch_counts() {
        let f = DispatchCounting::new(Linear::new(-0.4, 2));
        let z0 = [1.0f32, -1.0, 0.5, 2.0, -0.3, 0.9]; // B = 3
        let tab = tableau::rk4();
        let opts = IntegrateOpts::fixed(0.25); // 8 steps for every sample
        let bt = integrate_batch(&f, 0.0, 2.0, &z0, tab, &opts).unwrap();
        for tr in &bt.tracks {
            assert_eq!(tr.steps(), 8);
        }

        f.eval_batch_calls.set(0);
        let lam = [1.0f32; 6];
        let gs = aca_backward_batch(&f, tab, &bt, &lam);
        // 8 rounds × 4 stages, each one batched dispatch over all 3 samples.
        assert_eq!(f.eval_batch_calls.get(), 8 * 4, "stage recompute dispatches");
        assert_eq!(f.vjp_batch_calls.get(), 8 * 4, "reverse sweep dispatches");
        assert_eq!(f.scalar_vjp_calls.get(), 0, "no per-sample scalar fallback");
        // Per-sample meters still count per-sample work, like the scalar path.
        for g in &gs {
            assert_eq!(g.meter.nfe_backward, 8 * 4);
            assert_eq!(g.meter.vjp_calls, 8 * 4);
        }
    }

    /// Retirement path: samples with different step counts share rounds
    /// until the shallow one's reverse index underflows, and every result
    /// stays bit-identical to the scalar backward over the same trajectory.
    #[test]
    fn mismatched_step_counts_retire_and_match_scalar() {
        // Same setup as ode::batch's `samples_can_finish_at_different_rounds`:
        // initial conditions guaranteed to produce different step counts.
        let f = VanDerPol::new(1.0);
        let z0 = [0.01f32, 0.0, 2.0, 2.0];
        let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
        let tab = tableau::rk23();
        let bt = integrate_batch(&f, 0.0, 5.0, &z0, tab, &opts).unwrap();
        assert_ne!(bt.steps(0), bt.steps(1), "workloads should differ");

        let lam = [1.0f32, -0.5, 0.3, 0.9];
        let gb = aca_backward_batch(&f, tab, &bt, &lam);
        for i in 0..2 {
            let traj = bt.to_trajectory(i);
            let ga = aca_backward(&f, tab, &traj, &lam[i * 2..(i + 1) * 2]);
            assert_eq!(gb[i].dl_dz0, ga.dl_dz0, "sample {i}");
            assert_eq!(gb[i].meter.nfe_backward, ga.meter.nfe_backward, "sample {i}");
            assert_eq!(gb[i].meter.vjp_calls, ga.meter.vjp_calls, "sample {i}");
        }
    }

    /// Mixed per-sample spans: the reverse sweep keys every round off each
    /// sample's own `(t, h, z)` track, so trajectories with different
    /// endpoints co-batch bit-identically to scalar backward passes.
    #[test]
    fn mixed_span_batch_backward_matches_scalar() {
        use crate::ode::integrate_batch_spans;
        let f = VanDerPol::new(0.5);
        let z0 = [2.0f32, 0.0, -1.2, 0.7, 0.4, 1.1];
        let t1s = [1.0f64, 2.5, 0.6];
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let tab = tableau::dopri5();
        let bt = integrate_batch_spans(&f, 0.0, &t1s, &z0, tab, &opts).unwrap();
        let lam = [1.0f32, -0.5, 0.3, 0.9, -1.0, 0.2];
        let gb = aca_backward_batch(&f, tab, &bt, &lam);
        for (i, &t1) in t1s.iter().enumerate() {
            let traj = integrate(&f, 0.0, t1, &z0[i * 2..(i + 1) * 2], tab, &opts).unwrap();
            let ga = aca_backward(&f, tab, &traj, &lam[i * 2..(i + 1) * 2]);
            assert_eq!(gb[i].dl_dz0, ga.dl_dz0, "sample {i} dl_dz0");
            assert_eq!(gb[i].dl_dtheta, ga.dl_dtheta, "sample {i} dl_dtheta");
            assert_eq!(gb[i].meter.nfe_backward, ga.meter.nfe_backward, "sample {i}");
            assert_eq!(gb[i].meter.vjp_calls, ga.meter.vjp_calls, "sample {i}");
            assert_eq!(gb[i].meter.checkpoint_bytes, ga.meter.checkpoint_bytes, "sample {i}");
        }
    }

    #[test]
    fn matches_per_sample_aca_bitwise() {
        let f = VanDerPol::new(0.4);
        let z0 = [2.0f32, 0.0, -1.2, 0.7, 0.4, 1.1];
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let tab = tableau::dopri5();
        let bt = integrate_batch(&f, 0.0, 2.5, &z0, tab, &opts).unwrap();
        let lam = [1.0f32, -0.5, 0.3, 0.9, -1.0, 0.2];
        let gb = aca_backward_batch(&f, tab, &bt, &lam);
        for i in 0..3 {
            let traj = integrate(&f, 0.0, 2.5, &z0[i * 2..(i + 1) * 2], tab, &opts).unwrap();
            let ga = aca_backward(&f, tab, &traj, &lam[i * 2..(i + 1) * 2]);
            assert_eq!(gb[i].dl_dz0, ga.dl_dz0, "sample {i}");
            assert_eq!(gb[i].meter.nfe_backward, ga.meter.nfe_backward);
            assert_eq!(gb[i].meter.vjp_calls, ga.meter.vjp_calls);
            assert_eq!(gb[i].meter.checkpoint_bytes, ga.meter.checkpoint_bytes);
        }
    }

    /// The paper's toy problem per sample: dL/dz0 = 2 z0 exp(2kT).
    #[test]
    fn toy_gradient_accuracy_per_sample() {
        let k = -0.5f32;
        let f = Linear::new(k, 1);
        let z0 = [1.0f32, 2.0, -1.5];
        let t_end = 3.0;
        let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
        let bt = integrate_batch(&f, 0.0, t_end, &z0, tableau::dopri5(), &opts).unwrap();
        let lam: Vec<f32> = (0..3).map(|i| 2.0 * bt.last(i)[0]).collect();
        let g = aca_backward_batch(&f, tableau::dopri5(), &bt, &lam);
        for i in 0..3 {
            let exact = f.exact_dl_dz0(z0[i], t_end);
            let rel = ((g[i].dl_dz0[0] as f64 - exact) / exact).abs();
            assert!(rel < 1e-4, "sample {i}: {} vs {exact} (rel {rel})", g[i].dl_dz0[0]);
        }
    }

    #[test]
    fn backward_batch_dispatches_all_methods() {
        let f = Linear::new(-0.3, 2);
        let z0 = [1.0f32, -1.0, 0.5, 2.0];
        let opts = IntegrateOpts { record_trials: true, ..IntegrateOpts::with_tol(1e-6, 1e-8) };
        let tab = tableau::dopri5();
        let bt = integrate_batch(&f, 0.0, 2.0, &z0, tab, &opts).unwrap();
        let lam = [1.0f32, 0.0, 0.0, 1.0];
        for method in Method::all() {
            let gs = backward_batch(&f, tab, &bt, &lam, method, &opts).unwrap();
            assert_eq!(gs.len(), 2, "{}", method.name());
            let exact = (-0.3f64 * 2.0).exp(); // dz(T)/dz0 = e^{kT} per component
            for (i, g) in gs.iter().enumerate() {
                let nz: f64 = g.dl_dz0.iter().map(|v| *v as f64).sum();
                assert!(
                    (nz - exact).abs() < 0.05 * exact,
                    "{} sample {i}: {nz} vs {exact}",
                    method.name()
                );
            }
        }
    }
}
