//! **Adaptive Checkpoint Adjoint** — the paper's Algorithm 2 backward pass.
//!
//! The forward pass ([`crate::ode::integrate`]) already implemented the ACA
//! forward strategy: accepted discretization points and *values* were kept,
//! the step-size-search computation graphs were deleted. Here we walk the
//! checkpoints in reverse; for each step we re-run the local forward from the
//! saved `(t_i, h_i, z_i)` — guaranteeing the reverse-mode trajectory equals
//! the forward-mode trajectory *exactly* — apply the local step adjoint, and
//! delete the local graph again.
//!
//! Costs (paper Table 1): computation `O(N_f × N_t × (m+1))`, memory
//! `O(N_f + N_t)`, graph depth `O(N_f × N_t)`.

use super::step_vjp::step_vjp;
use super::{CostMeter, GradResult};
use crate::ckpt::SegmentCache;
use crate::ode::func::OdeFunc;
use crate::ode::integrate::Trajectory;
use crate::ode::tableau::Tableau;

/// Run the ACA backward pass over a recorded trajectory.
///
/// * `lam_t1` — `dL/dz(T)` from the loss head.
///
/// Checkpoints are fetched through a [`SegmentCache`]: a dense store hands
/// them out directly (bit-for-bit the old behavior); a thinned store
/// ([`crate::ckpt`]) replays each dropped state from its nearest anchor
/// **once per segment** — bit-identical to the forward state, with the
/// replay evaluations metered into [`CostMeter::nfe_replay`].
///
/// Returns `dL/dz(0)`, `dL/dθ` and the cost instrumentation.
pub fn aca_backward<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &Trajectory,
    lam_t1: &[f32],
) -> GradResult {
    assert_eq!(lam_t1.len(), f.dim());
    let n = traj.len();
    let mut lam = lam_t1.to_vec();
    let mut dtheta = vec![0.0f32; f.n_params()];
    let mut meter = CostMeter {
        nfe_forward: traj.nfe,
        checkpoint_bytes: traj.checkpoint_bytes(),
        n_steps: n,
        n_rejected: traj.n_rejected,
        ..Default::default()
    };
    let mut cache = SegmentCache::new();

    // Reverse sweep over the saved discretization points (Algo 2).
    for i in (0..n).rev() {
        let t_i = traj.ts[i];
        let h_i = traj.h(i);
        let z_i = traj.state(f, tab, i, &mut cache);
        // Local forward + local backward; local graph freed on return.
        let out = step_vjp(f, tab, t_i, h_i, z_i, &lam, &mut dtheta, false);
        lam = out.dz;
        meter.nfe_backward += out.nfe;
        meter.vjp_calls += out.nvjp;
        // Depth: one chained VJP sweep per accepted step.
        meter.graph_depth += out.nvjp;
    }
    meter.nfe_replay = cache.nfe_replay;
    meter.replay_peak_bytes = cache.peak_bytes();

    GradResult { dl_dz0: lam, dl_dtheta: dtheta, meter }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::Linear;
    use crate::ode::{integrate, tableau, IntegrateOpts};

    /// The paper's toy problem (Eq. 27–29): L = z(T)², exact
    /// dL/dz0 = 2 z0 exp(2kT). ACA must match to solver accuracy.
    #[test]
    fn toy_problem_gradient_accuracy() {
        let k = -0.5f32;
        let z0 = 1.0f32;
        for t_end in [1.0f64, 3.0, 6.0] {
            let f = Linear::new(k, 1);
            let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
            let traj = integrate(&f, 0.0, t_end, &[z0], tableau::dopri5(), &opts).unwrap();
            let zt = traj.last().unwrap()[0];
            let lam = [2.0 * zt];
            let g = aca_backward(&f, tableau::dopri5(), &traj, &lam);
            let exact = f.exact_dl_dz0(z0, t_end);
            let rel = ((g.dl_dz0[0] as f64 - exact) / exact).abs();
            assert!(rel < 1e-4, "T={t_end}: {} vs {} (rel {rel})", g.dl_dz0[0], exact);
            // Parameter gradient too.
            let exact_k = f.exact_dl_dk(z0, t_end);
            let rel_k = ((g.dl_dtheta[0] as f64 - exact_k) / exact_k).abs();
            assert!(rel_k < 1e-3, "T={t_end}: dk {} vs {}", g.dl_dtheta[0], exact_k);
        }
    }

    /// Gradient of a fixed-step solve is the exact discrete gradient:
    /// z_N = R(kh)^N z0, dL/dz0 = 2 z_N R^N for L = z_N².
    #[test]
    fn fixed_step_exact_discrete_gradient() {
        let f = Linear::new(-1.0, 1);
        let tab = tableau::rk4();
        let traj = integrate(&f, 0.0, 1.0, &[1.0], tab, &IntegrateOpts::fixed(0.1)).unwrap();
        let zt = traj.last().unwrap()[0] as f64;
        // R per step:
        let r = (traj.z(1).unwrap()[0] as f64) / (traj.z(0).unwrap()[0] as f64);
        let lam = [(2.0 * zt) as f32];
        let g = aca_backward(&f, tab, &traj, &lam);
        let exact = 2.0 * zt * r.powi(10);
        assert!(
            ((g.dl_dz0[0] as f64 - exact) / exact).abs() < 1e-5,
            "{} vs {}",
            g.dl_dz0[0],
            exact
        );
    }

    /// Meter: backward nfe = stages × N_t; depth counts vjp sweeps.
    #[test]
    fn meter_accounting() {
        let f = Linear::new(-1.0, 1);
        let tab = tableau::rk4();
        let traj = integrate(&f, 0.0, 1.0, &[1.0], tab, &IntegrateOpts::fixed(0.25)).unwrap();
        let g = aca_backward(&f, tab, &traj, &[1.0]);
        assert_eq!(g.meter.n_steps, 4);
        assert_eq!(g.meter.nfe_backward, 4 * 4);
        assert_eq!(g.meter.vjp_calls, 4 * 4);
        assert!(g.meter.checkpoint_bytes > 0);
    }

    /// A memory-budgeted checkpoint store changes *where* states live, not
    /// what the backward pass sees: gradients, dθ and every classic meter
    /// stay bit-identical to the dense store; only `nfe_replay` (and the
    /// smaller `checkpoint_bytes`) differ.
    #[test]
    fn thinned_store_gradients_bit_equal_dense() {
        use crate::ckpt::CkptPolicy;
        let f = crate::ode::analytic::VanDerPol::new(0.5);
        let tab = tableau::dopri5();
        let dense_opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let dense = integrate(&f, 0.0, 3.0, &[1.8, -0.2], tab, &dense_opts).unwrap();
        let lam = [1.0f32, -0.5];
        let gd = aca_backward(&f, tab, &dense, &lam);
        assert_eq!(gd.meter.nfe_replay, 0, "dense store never replays");

        let budget = dense.store.bytes() / 4;
        for policy in [CkptPolicy::EveryK(4), CkptPolicy::Budgeted(budget)] {
            let opts = IntegrateOpts { ckpt: policy, ..IntegrateOpts::with_tol(1e-6, 1e-8) };
            let thin = integrate(&f, 0.0, 3.0, &[1.8, -0.2], tab, &opts).unwrap();
            assert_eq!(thin.ts, dense.ts, "{policy:?}: grid");
            assert_eq!(thin.last(), dense.last(), "{policy:?}: final state");
            let gt = aca_backward(&f, tab, &thin, &lam);
            assert_eq!(gt.dl_dz0, gd.dl_dz0, "{policy:?}: dl_dz0");
            assert_eq!(gt.dl_dtheta, gd.dl_dtheta, "{policy:?}: dl_dtheta");
            assert_eq!(gt.meter.nfe_backward, gd.meter.nfe_backward, "{policy:?}");
            assert_eq!(gt.meter.vjp_calls, gd.meter.vjp_calls, "{policy:?}");
            assert!(gt.meter.nfe_replay > 0, "{policy:?}: thinning must replay");
            assert!(
                gt.meter.replay_peak_bytes > 0,
                "{policy:?}: the replay buffer must be metered"
            );
            assert_eq!(gd.meter.replay_peak_bytes, 0, "dense never buffers a segment");
            assert!(
                gt.meter.checkpoint_bytes < gd.meter.checkpoint_bytes,
                "{policy:?}: thinned store must hold fewer bytes"
            );
        }
    }

    /// Multi-dimensional state: gradient distributes element-wise for the
    /// diagonal linear system.
    #[test]
    fn multidim_gradient() {
        let f = Linear::new(-0.3, 4);
        let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
        let traj =
            integrate(&f, 0.0, 2.0, &[1.0, 2.0, -1.0, 0.5], tableau::rk23(), &opts).unwrap();
        let lam = [1.0f32, 0.0, 2.0, 0.0];
        let g = aca_backward(&f, tableau::rk23(), &traj, &lam);
        let r = (-0.3f64 * 2.0).exp();
        assert!((g.dl_dz0[0] as f64 - r).abs() < 1e-4);
        assert!(g.dl_dz0[1].abs() < 1e-6);
        assert!((g.dl_dz0[2] as f64 - 2.0 * r).abs() < 1e-4);
    }
}
