//! Gradient estimation for Neural ODEs — the paper's Section 3.
//!
//! Three numerical realizations of the analytical adjoint solution
//! (paper Theorem 2.1), all driven by the same forward [`crate::ode`] pass:
//!
//! * [`aca`] — **Adaptive Checkpoint Adjoint** (the paper's contribution,
//!   Algo 2): replay each accepted step from the saved `(t_i, h_i, z_i)`
//!   checkpoint and run the exact discrete step adjoint. Reverse-accurate,
//!   shallow graph `O(N_f × N_t)`, memory `O(N_f + N_t)`.
//! * [`naive`] — direct backprop through the solver *including* the
//!   step-size search: the same step adjoints plus gradient flow through the
//!   rejected trials and the `h_{i+1} = h_i · decay(ê_i)` recursion
//!   (paper Eq. 23–26). Depth `O(N_f × N_t × m)`.
//! * [`adjoint`] — the continuous adjoint of Chen et al. (2018): forget the
//!   forward trajectory, solve the augmented ODE backward. Memory `O(N_f)`
//!   but reverse-inaccurate (paper Theorem 3.2).
//!
//! All methods return a [`GradResult`] with `dL/dz0`, `dL/dθ`, and a
//! [`CostMeter`] whose fields instrument the paper's Table 1 columns.
//!
//! Batched trajectories go through [`aca_backward_batch`] /
//! [`backward_batch`]: the ACA reverse sweep is **shared-stage** — all
//! samples sharing a reverse round run their stage recomputation and
//! ŵ-sweep through one [`step_vjp_batch`] call (one
//! [`crate::ode::OdeFunc::eval_batch`] / `vjp_batch` dispatch per stage),
//! symmetric to the forward engine's stage sweeps, while per-sample results
//! and meters stay bit-identical to the scalar path.

pub mod aca;
pub mod adjoint;
pub mod batch;
pub mod naive;
pub mod step_vjp;

pub use aca::aca_backward;
pub use adjoint::{adjoint_backward, AdjointOpts};
pub use batch::{aca_backward_batch, backward_batch};
pub use naive::naive_backward;
pub use step_vjp::{err_norm_vjp, step_vjp, step_vjp_batch, StepVjp, StepVjpBatchScratch};

/// Which gradient-estimation method to use (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Adaptive Checkpoint Adjoint (ours / the paper's).
    Aca,
    /// Direct backprop through the solver incl. step-size search.
    Naive,
    /// Continuous adjoint (Chen et al. 2018).
    Adjoint,
}

impl Method {
    pub fn all() -> [Method; 3] {
        [Method::Aca, Method::Naive, Method::Adjoint]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Aca => "aca",
            Method::Naive => "naive",
            Method::Adjoint => "adjoint",
        }
    }
}

impl std::str::FromStr for Method {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "aca" => Ok(Method::Aca),
            "naive" => Ok(Method::Naive),
            "adjoint" => Ok(Method::Adjoint),
            other => Err(format!("unknown gradient method '{other}' (aca|naive|adjoint)")),
        }
    }
}

/// Instrumentation of one forward+backward pass — measured counterparts of
/// the paper's Table 1 columns.
#[derive(Debug, Clone, Default)]
pub struct CostMeter {
    /// `f` evaluations in the forward pass (`N_f × N_t × m` term).
    pub nfe_forward: usize,
    /// `f` evaluations in the backward pass (stage recomputation; ACA's
    /// `(m+1)`-th pass, the adjoint's `N_r` reverse solve).
    pub nfe_backward: usize,
    /// `f` evaluations spent regenerating **thinned checkpoints** by
    /// segment replay (see [`crate::ckpt`]). Zero for a dense store; kept
    /// separate from `nfe_backward` so the Table 1/2 accounting of the
    /// paper's methods stays honest while the memory budget's recompute
    /// overhead stays visible.
    pub nfe_replay: usize,
    /// Peak bytes of the backward pass's segment-replay buffer
    /// ([`crate::ckpt::SegmentCache::peak_bytes`]) — the `O(stride × D)`
    /// transient a thinned store trades its resident budget against. Zero
    /// for a dense store.
    pub replay_peak_bytes: usize,
    /// VJP sweeps in the backward pass.
    pub vjp_calls: usize,
    /// Peak bytes held by trajectory checkpoints (`O(N_t)` memory term).
    pub checkpoint_bytes: usize,
    /// Longest chain of sequentially-dependent VJP applications — the
    /// measured "depth of computation graph" column.
    pub graph_depth: usize,
    /// Accepted forward steps `N_t`.
    pub n_steps: usize,
    /// Rejected forward trials (`Σ (m_i − 1)`).
    pub n_rejected: usize,
    /// Reverse-solve steps `N_r` (adjoint method only).
    pub n_reverse_steps: usize,
}

/// Gradients of a scalar loss w.r.t. the ODE initial state and parameters.
#[derive(Debug, Clone)]
pub struct GradResult {
    /// `dL/dz(0)` — flows to upstream layers (the encoder).
    pub dl_dz0: Vec<f32>,
    /// `dL/dθ` for the dynamics parameters.
    pub dl_dtheta: Vec<f32>,
    /// Cost instrumentation for Table 1.
    pub meter: CostMeter,
}

/// Unified entry point: run the backward pass of `method` for a loss whose
/// gradient at the final state is `lam_t1`.
///
/// `traj` must come from [`crate::ode::integrate`] over `[t0, t1]`; the naive
/// method additionally requires it to have been recorded with
/// `record_trials = true` when the solver is adaptive.
pub fn backward<F: crate::ode::OdeFunc + ?Sized>(
    f: &F,
    tab: &crate::ode::Tableau,
    traj: &crate::ode::Trajectory,
    lam_t1: &[f32],
    method: Method,
    opts: &crate::ode::IntegrateOpts,
) -> anyhow::Result<GradResult> {
    match method {
        Method::Aca => Ok(aca_backward(f, tab, traj, lam_t1)),
        Method::Naive => Ok(naive_backward(f, tab, traj, lam_t1, opts)),
        Method::Adjoint => {
            adjoint_backward(f, tab, traj, lam_t1, &AdjointOpts::from_integrate(opts))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parsing() {
        assert_eq!("ACA".parse::<Method>().unwrap(), Method::Aca);
        assert_eq!("adjoint".parse::<Method>().unwrap(), Method::Adjoint);
        assert!("rk4".parse::<Method>().is_err());
    }

    #[test]
    fn method_names_round_trip() {
        for m in Method::all() {
            assert_eq!(m.name().parse::<Method>().unwrap(), m);
        }
    }
}
