//! The **naive method**: direct back-propagation through the ODE solver,
//! *including the step-size search* (paper Sec 3.1 / 3.3).
//!
//! The forward pass of an adaptive solver computes, per accepted step
//! (paper Eq. 23–26):
//!
//! ```text
//! err_0 = ê(t_i, h_0, z_i)          h_1 = h_0 · factor(err_0)    (rejected)
//! …
//! err_{m−1}                          h_m = h_{m−1} · factor(err_{m−1})
//! z_{i+1} = ψ_{h_m}(t_i, z_i)        h_{i+1,0} = h_m · factor(err_m)
//! ```
//!
//! PyTorch-style autograd treats every `h` as a recursive function of its
//! predecessors, so gradients flow through the whole chain — `O(N_f·N_t·m)`
//! graph depth. ACA instead treats `h_m` as a constant. This module
//! reproduces the naive behaviour exactly: on top of the per-step adjoint it
//! chains `dL/dh` backward through accepted and rejected trials via the
//! controller derivative ([`crate::ode::Controller::dfactor_derr`]) and the
//! error-estimate VJP ([`super::err_norm_vjp`]).
//!
//! For **fixed-step** solves there is no search and the naive gradient
//! coincides with ACA (asserted by tests).

use super::step_vjp::{err_norm_vjp, step_vjp};
use super::{CostMeter, GradResult};
use crate::ckpt::SegmentCache;
use crate::ode::controller::Controller;
use crate::ode::func::OdeFunc;
use crate::ode::integrate::{IntegrateOpts, Trajectory};
use crate::ode::tableau::Tableau;

/// Run the naive backward pass over a trajectory recorded with
/// `record_trials = true` (adaptive) or any trajectory (fixed-step).
pub fn naive_backward<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &Trajectory,
    lam_t1: &[f32],
    opts: &IntegrateOpts,
) -> GradResult {
    assert_eq!(lam_t1.len(), f.dim());
    let n = traj.len();
    let adaptive = tab.adaptive() && opts.fixed_h.is_none();
    let ctrl = opts.controller.unwrap_or_else(|| Controller::for_tableau(tab));

    let mut lam = lam_t1.to_vec();
    let mut dtheta = vec![0.0f32; f.n_params()];
    let mut meter = CostMeter {
        nfe_forward: traj.nfe,
        n_steps: n,
        n_rejected: traj.n_rejected,
        ..Default::default()
    };
    // The naive method holds the whole graph: checkpoints *and* every trial's
    // stage activations. Memory column of Table 1: O(N_f × N_t × m).
    let per_step_graph = tab.stages * f.dim() * std::mem::size_of::<f32>();
    meter.checkpoint_bytes =
        traj.checkpoint_bytes() + (n + traj.n_rejected) * per_step_graph;

    // ν = dL/d(h entering the current step's trial chain from the *previous*
    // accepted step's controller update). Chained right-to-left.
    let mut nu: f64 = 0.0;
    // Checkpoint access goes through the segment cache so a thinned store
    // (crate::ckpt) replays dropped states bit-exactly; dense stores hand
    // them out directly.
    let mut cache = SegmentCache::new();

    for i in (0..n).rev() {
        let t_i = traj.ts[i];
        let h_i = traj.h(i);
        let z_i = traj.state(f, tab, i, &mut cache);

        // (1) Adjoint of the accepted step ψ. The *final* step's h was
        // clamped to land exactly on T (h = T − t_{N−1}); autograd through
        // the clamp would distribute −dL/dh over all earlier steps' h. We
        // treat the clamp as a constant (see DESIGN.md §6), so the final
        // step contributes no h-gradient.
        let want_dh = adaptive && i + 1 < n;
        let out = step_vjp(f, tab, t_i, h_i, z_i, &lam, &mut dtheta, want_dh);
        let mut lam_next = out.dz;
        meter.nfe_backward += out.nfe;
        meter.vjp_calls += out.nvjp;
        meter.graph_depth += out.nvjp;

        if adaptive {
            // (2) dL/dh_i: explicit step path + the next step's initial-trial
            //     path  h_{i+1,0} = h_i · factor(err_i).
            let mut dl_dh = out.dh;
            if nu != 0.0 {
                let err_i = traj.errs[i];
                let factor = ctrl.factor(err_i, 0.0);
                dl_dh += nu * factor;
                // ∂h_{i+1,0}/∂err_i = h_i · dfactor.
                let dfac = ctrl.dfactor_derr(err_i, 0.0);
                if dfac != 0.0 {
                    let gbar_err = nu * h_i * dfac;
                    let (deh, nfe, nvjp) = err_norm_vjp(
                        f, tab, t_i, h_i, z_i, opts.atol, opts.rtol, gbar_err,
                        &mut lam_next, &mut dtheta,
                    );
                    dl_dh += deh;
                    meter.nfe_backward += nfe;
                    meter.vjp_calls += nvjp;
                    meter.graph_depth += nvjp;
                }
            }

            // (3) Chain backward through this step's rejected trials:
            //     h_{j+1} = h_j · factor(err(h_j, z_i, θ)).
            let empty: Vec<crate::ode::TrialRecord> = Vec::new();
            let trials = traj.trials.get(i).unwrap_or(&empty);
            for tr in trials.iter().rev() {
                if dl_dh == 0.0 {
                    break;
                }
                if !tr.err.is_finite() {
                    // Non-finite trial: the 0.5 halving has zero err-gradient.
                    dl_dh *= 0.5;
                    continue;
                }
                let factor = {
                    // A rejected step's factor is clamped to <= 1.
                    let raw = ctrl.factor(tr.err, 0.0);
                    raw.min(1.0)
                };
                let dfac = if ctrl.factor(tr.err, 0.0) >= 1.0 {
                    0.0 // the min(·,1) clamp was active
                } else {
                    ctrl.dfactor_derr(tr.err, 0.0)
                };
                if dfac != 0.0 {
                    let gbar_err = dl_dh * tr.h * dfac;
                    let (deh, nfe, nvjp) = err_norm_vjp(
                        f, tab, t_i, tr.h, z_i, opts.atol, opts.rtol, gbar_err,
                        &mut lam_next, &mut dtheta,
                    );
                    dl_dh = dl_dh * factor + deh;
                    meter.nfe_backward += nfe;
                    meter.vjp_calls += nvjp;
                    meter.graph_depth += nvjp;
                } else {
                    dl_dh *= factor;
                }
            }
            // What remains is the gradient w.r.t. this step's initial trial
            // h_{i,0}, which came from step i−1's controller.
            nu = dl_dh;
        }

        lam = lam_next;
    }
    meter.nfe_replay = cache.nfe_replay;
    meter.replay_peak_bytes = cache.peak_bytes();

    GradResult { dl_dz0: lam, dl_dtheta: dtheta, meter }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::{integrate, tableau};

    /// Fixed-step: naive must equal ACA bit-for-bit (no search to backprop
    /// through — paper Sec 3.3 "the output of the forward pass is the same").
    #[test]
    fn fixed_step_equals_aca() {
        let f = VanDerPol::new(0.15);
        let tab = tableau::rk4();
        let opts = IntegrateOpts::fixed(0.05);
        let traj = integrate(&f, 0.0, 2.0, &[2.0, 0.0], tab, &opts).unwrap();
        let lam = [1.0f32, -0.5];
        let g_naive = naive_backward(&f, tab, &traj, &lam, &opts);
        let g_aca = super::super::aca_backward(&f, tab, &traj, &lam);
        assert_eq!(g_naive.dl_dz0, g_aca.dl_dz0);
    }

    /// Adaptive on the toy problem: the naive gradient stays close to the
    /// analytic gradient, but its extra h-chain terms — legitimate gradients
    /// of the discrete map the naive method differentiates — make it deviate
    /// *more* than ACA (the paper's Fig 6 ordering).
    #[test]
    fn adaptive_toy_gradient_close_but_worse_than_aca() {
        let f = Linear::new(-0.5, 1);
        let tab = tableau::dopri5();
        let opts = IntegrateOpts {
            record_trials: true,
            ..IntegrateOpts::with_tol(1e-6, 1e-8)
        };
        let traj = integrate(&f, 0.0, 4.0, &[1.0], tab, &opts).unwrap();
        let zt = traj.last().unwrap()[0];
        let exact = f.exact_dl_dz0(1.0, 4.0);
        let g_naive = naive_backward(&f, tab, &traj, &[2.0 * zt], &opts);
        let g_aca = super::super::aca_backward(&f, tab, &traj, &[2.0 * zt]);
        let rel_naive = ((g_naive.dl_dz0[0] as f64 - exact) / exact).abs();
        let rel_aca = ((g_aca.dl_dz0[0] as f64 - exact) / exact).abs();
        assert!(rel_naive < 5e-2, "naive diverged: {rel_naive}");
        assert!(
            rel_naive > rel_aca,
            "naive ({rel_naive}) should be less accurate than ACA ({rel_aca})"
        );
    }

    /// The naive method's accounted memory exceeds ACA's on the same solve
    /// (Table 1: O(N_f·N_t·m) vs O(N_f + N_t)).
    #[test]
    fn memory_accounting_dominates_aca() {
        let f = VanDerPol::new(2.0);
        let tab = tableau::dopri5();
        let opts = IntegrateOpts {
            record_trials: true,
            h0: Some(1.0),
            ..IntegrateOpts::with_tol(1e-6, 1e-8)
        };
        let traj = integrate(&f, 0.0, 5.0, &[2.0, 0.0], tab, &opts).unwrap();
        let lam = [1.0f32, 0.0];
        let g_naive = naive_backward(&f, tab, &traj, &lam, &opts);
        let g_aca = super::super::aca_backward(&f, tab, &traj, &lam);
        assert!(
            g_naive.meter.checkpoint_bytes > g_aca.meter.checkpoint_bytes,
            "naive {} <= aca {}",
            g_naive.meter.checkpoint_bytes,
            g_aca.meter.checkpoint_bytes
        );
    }

    /// Graph depth: naive >= ACA, strictly greater when rejections occurred.
    #[test]
    fn graph_depth_deeper_with_rejections() {
        let f = VanDerPol::new(3.0);
        let tab = tableau::dopri5();
        let opts = IntegrateOpts {
            record_trials: true,
            h0: Some(2.0),
            ..IntegrateOpts::with_tol(1e-5, 1e-7)
        };
        let traj = integrate(&f, 0.0, 4.0, &[2.0, 0.0], tab, &opts).unwrap();
        assert!(traj.n_rejected > 0, "need rejections for this test");
        let lam = [1.0f32, 0.0];
        let g_naive = naive_backward(&f, tab, &traj, &lam, &opts);
        let g_aca = super::super::aca_backward(&f, tab, &traj, &lam);
        assert!(
            g_naive.meter.graph_depth > g_aca.meter.graph_depth,
            "naive depth {} <= aca depth {}",
            g_naive.meter.graph_depth,
            g_aca.meter.graph_depth
        );
    }

    /// With a zero upstream gradient everything is zero and cheap.
    #[test]
    fn zero_gradient_propagates() {
        let f = Linear::new(1.0, 2);
        let tab = tableau::heun_euler();
        let opts = IntegrateOpts { record_trials: true, ..Default::default() };
        let traj = integrate(&f, 0.0, 1.0, &[1.0, 1.0], tab, &opts).unwrap();
        let g = naive_backward(&f, tab, &traj, &[0.0, 0.0], &opts);
        assert!(g.dl_dz0.iter().all(|&v| v == 0.0));
        assert!(g.dl_dtheta.iter().all(|&v| v == 0.0));
    }
}
