//! `ckpt` — memory-budgeted checkpoint storage with **bit-exact** segment
//! replay.
//!
//! ACA (paper Algo 2) records every accepted state of the forward solve so
//! the backward pass can replay each step from its exact start state. That
//! makes checkpoint memory `O(N_t · D)` — the one resource axis a
//! long-horizon or large-batch solve can blow through. ANODE (Gholami et
//! al.) and MALI (Zhuang et al.) show the same gradient accuracy is
//! reachable under a **memory budget**: keep sparse anchor states, recompute
//! the dropped ones from the nearest anchor when the backward pass asks for
//! them.
//!
//! ## Why bit-exactness survives thinning
//!
//! The trajectory spine keeps the accepted step sizes `hs` **exactly as the
//! stepper used them** (recovering them from `ts` differences would lose a
//! ulp). Re-running [`rk_step`](crate::ode::rk_step) from an anchor `z_a`
//! with the recorded `h` sequence therefore performs the *identical*
//! floating-point computation the forward pass performed — stage 0 is
//! `f(t, z)` at bitwise-equal arguments whether it was FSAL-reused or
//! evaluated fresh (pinned by `prop_checkpoint_replay_is_bit_exact`) — so a
//! replayed state equals the dropped state **bit-for-bit**, and every
//! gradient computed through a thinned store equals the dense-store gradient
//! bit-for-bit (pinned by `prop_budgeted_ckpt_grads_bit_equal_dense`).
//! ACA's accuracy guarantee is a statement about *which* states the backward
//! pass sees, not about where they are stored.
//!
//! ## Recompute-vs-store trade-off
//!
//! | policy                    | states held        | extra forward cost      |
//! |---------------------------|--------------------|-------------------------|
//! | [`CkptPolicy::Dense`]     | all `N_t + 1`      | none (today's behavior) |
//! | [`CkptPolicy::EveryK`]    | `~N_t / K` + tail  | ≤ `K − 1` steps/segment |
//! | [`CkptPolicy::Budgeted`]  | `≤ budget / (4D)`  | ≤ stride − 1 steps/seg  |
//!
//! A reverse sweep with a [`SegmentCache`] replays each segment **once**
//! (the cache holds the segment while the sweep walks down through it), so
//! the amortized overhead is one extra forward evaluation per *dropped*
//! state — ANODE's recompute bound. Replay evaluations are metered into
//! [`CostMeter::nfe_replay`](crate::grad::CostMeter::nfe_replay), never into
//! `nfe_backward`, so the paper's Table 1/2 accounting stays honest. The
//! same meter feeds the tracing layer: a traced request's `replay` span
//! (see [`crate::obs`]) carries `nfe_replay` and `replay_peak_bytes`, so
//! per-request replay cost is attributed in the trace exactly as it is in
//! the aggregate tables.
//!
//! `Budgeted` thins **live**: whenever storing the next state would push the
//! anchor count over `budget / (4D)`, the keep-stride doubles and off-stride
//! anchors are dropped immediately — the budget holds *mid-solve*, not just
//! at the end. Anchors stay evenly spread (multiples of the stride, plus the
//! initial state and the running tail), which is the `~√N_t`-anchor layout
//! when the budget is chosen `∝ √N_t`.
//!
//! Follow-on headroom (see ROADMAP): MALI-style O(1) *reversible* storage —
//! reconstruct `z_i` from `z_{i+1}` instead of replaying from an anchor —
//! would drop even the anchors.

use crate::ode::func::OdeFunc;
use crate::ode::step::{rk_step, StepScratch};
use crate::ode::tableau::Tableau;

/// What the store keeps (policy of a [`CheckpointStore`] or of one
/// [`BatchTrajectory`](crate::ode::BatchTrajectory) track).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CkptPolicy {
    /// Keep every accepted state — today's behavior, bit-for-bit.
    #[default]
    Dense,
    /// Keep every `K`-th state (plus the initial state and the tail).
    /// `K = 0` or `1` degenerates to `Dense`.
    EveryK(usize),
    /// Keep at most `budget_bytes / (4 · dim)` evenly-spread anchors
    /// (clamped to at least 2 — the initial state and the tail), thinning
    /// live as the solve grows so the budget holds mid-flight.
    Budgeted(usize),
}

impl CkptPolicy {
    /// `Dense` for `budget_bytes == 0`, `Budgeted` otherwise — the shape the
    /// `NODAL_CKPT_BUDGET_BYTES` knob maps through.
    pub fn from_budget(budget_bytes: usize) -> Self {
        if budget_bytes == 0 {
            CkptPolicy::Dense
        } else {
            CkptPolicy::Budgeted(budget_bytes)
        }
    }
}

/// Clamp range for byte-budget knobs (nonzero values).
const BUDGET_MIN_BYTES: usize = 64;
const BUDGET_MAX_BYTES: usize = 1 << 40;

/// Clamp a byte budget to the supported range; `0` passes through (it means
/// "no budget"). The single clamp rule every budget knob — env-read or
/// hand-built config — goes through.
pub fn clamp_budget(bytes: usize) -> usize {
    if bytes == 0 {
        0
    } else {
        bytes.clamp(BUDGET_MIN_BYTES, BUDGET_MAX_BYTES)
    }
}

/// Parse a byte-budget env var **clamped at the source** like
/// `NODAL_WORKERS`: unset, unparseable or `0` means "no budget"; anything
/// else goes through [`clamp_budget`].
pub fn parse_budget_env(var: &str) -> usize {
    match std::env::var(var).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => clamp_budget(n),
        None => 0,
    }
}

/// Read `NODAL_CKPT_BUDGET_BYTES` — the per-sample checkpoint budget both
/// the serve worker and the trainer default to.
pub fn env_budget_bytes() -> usize {
    parse_budget_env("NODAL_CKPT_BUDGET_BYTES")
}

/// The thinning state machine shared by the scalar [`CheckpointStore`] and
/// the batched per-track stores: decides, for each new state, which
/// previously stored anchors to drop so the policy's invariant holds
/// *before* the new state lands.
///
/// Invariants maintained over the stored index set:
/// * index `0` is always kept (the replay base of the earliest segment);
/// * the most recently pushed state is always kept (the tail — `last()`
///   never replays);
/// * every other kept index is a multiple of the current `stride`;
/// * under `Budgeted`, the kept count never exceeds `cap` — the stride
///   doubles (and off-stride anchors drop) as soon as it would.
#[derive(Debug, Clone)]
pub struct Thinner {
    stride: usize,
    cap: Option<usize>,
}

impl Default for Thinner {
    fn default() -> Self {
        Thinner { stride: 1, cap: None }
    }
}

impl Thinner {
    /// Build the policy state for states of `dim` f32 components.
    pub fn new(policy: CkptPolicy, dim: usize) -> Self {
        match policy {
            CkptPolicy::Dense => Thinner { stride: 1, cap: None },
            CkptPolicy::EveryK(k) => Thinner { stride: k.max(1), cap: None },
            CkptPolicy::Budgeted(bytes) => {
                let state_bytes = dim.max(1) * std::mem::size_of::<f32>();
                Thinner { stride: 1, cap: Some((bytes / state_bytes).max(2)) }
            }
        }
    }

    fn on_grid(&self, j: usize) -> bool {
        j % self.stride.max(1) == 0
    }

    /// Plan the drops that must precede storing the next state. `stored` is
    /// the current anchor index set (ascending); `drops` is filled with the
    /// *positions* into `stored` to remove (ascending). May double the
    /// stride (Budgeted) until the post-push count fits the cap.
    pub fn plan_push(&mut self, stored: &[usize], drops: &mut Vec<usize>) {
        drops.clear();
        // The previous tail was only kept because it was the tail; once a
        // newer state arrives it must earn its place on the stride grid.
        if let Some(&j) = stored.last() {
            if j != 0 && !self.on_grid(j) {
                drops.push(stored.len() - 1);
            }
        }
        if let Some(cap) = self.cap {
            let mut kept = stored.len() - drops.len();
            while kept + 1 > cap {
                self.stride = self.stride.saturating_mul(2);
                drops.clear();
                kept = 0;
                for (p, &j) in stored.iter().enumerate() {
                    if j == 0 || self.on_grid(j) {
                        kept += 1;
                    } else {
                        drops.push(p);
                    }
                }
            }
        }
    }

    /// Current keep-stride (1 = dense).
    pub fn stride(&self) -> usize {
        self.stride
    }
}

/// Position of state `k` in a sorted anchor index set recorded under
/// `policy` — the single lookup rule the scalar store and the batched
/// tracks share (`Dense` never thins, so `idx[k] == k` and the search is
/// skipped on the default hot path).
pub(crate) fn anchor_pos(policy: CkptPolicy, idx: &[usize], k: usize) -> Option<usize> {
    if matches!(policy, CkptPolicy::Dense) {
        (k < idx.len()).then_some(k)
    } else {
        idx.binary_search(&k).ok()
    }
}

/// Greatest stored index `≤ k` in a sorted anchor index set (index 0 is
/// always stored) — shared by both stores.
pub(crate) fn anchor_floor(idx: &[usize], k: usize) -> usize {
    match idx.binary_search(&k) {
        Ok(p) => idx[p],
        Err(p) => idx[p.saturating_sub(1)],
    }
}

/// Drop-compaction driver shared by the scalar store and the batched
/// tracks — the one place that encodes [`Thinner::plan_push`]'s contract
/// (drops are **ascending positions**). Walks positions `0..len`, calling
/// `f(r, None)` for each dropped position and `f(r, Some(w))` for each
/// survivor (`r` = read position, `w` = its new write position); returns
/// the surviving count. One linear sweep, so a thin event costs
/// `O(anchors)` moves, never `O(anchors²)`.
pub(crate) fn compact_drops(
    len: usize,
    drops: &[usize],
    mut f: impl FnMut(usize, Option<usize>),
) -> usize {
    let mut w = 0usize;
    let mut di = 0usize;
    for r in 0..len {
        if di < drops.len() && drops[di] == r {
            di += 1;
            f(r, None);
            continue;
        }
        f(r, Some(w));
        w += 1;
    }
    w
}

/// State storage of one [`Trajectory`](crate::ode::Trajectory) behind a
/// [`CkptPolicy`]: a flat anchor arena plus the sorted anchor index set.
/// The trajectory spine (`ts`, `hs`, `errs`, `trials`) stays on the
/// trajectory itself — it is tiny (`O(N_t)` scalars) and is exactly what
/// replay needs to regenerate any dropped state bit-exactly.
#[derive(Debug, Clone, Default)]
pub struct CheckpointStore {
    dim: usize,
    policy: CkptPolicy,
    thin: Thinner,
    /// Total states recorded (`N_t + 1` after a solve), stored or not.
    n: usize,
    /// Stored state indices, ascending. `idx[p]`'s state is
    /// `buf[p·dim .. (p+1)·dim]`.
    idx: Vec<usize>,
    buf: Vec<f32>,
    drop_scratch: Vec<usize>,
    peak_bytes: usize,
}

impl CheckpointStore {
    /// Empty store for states of `dim` components under `policy`.
    pub fn new(dim: usize, policy: CkptPolicy) -> Self {
        CheckpointStore {
            dim,
            policy,
            thin: Thinner::new(policy, dim),
            ..Default::default()
        }
    }

    /// Rebuild a store from exported parts (the
    /// [`BatchTrajectory::to_trajectory`](crate::ode::BatchTrajectory::to_trajectory)
    /// interop path). `idx` must be ascending and `buf` flat `[idx.len() × dim]`.
    pub fn from_parts(
        dim: usize,
        policy: CkptPolicy,
        thin: Thinner,
        n: usize,
        idx: Vec<usize>,
        buf: Vec<f32>,
        peak_bytes: usize,
    ) -> Self {
        debug_assert_eq!(buf.len(), idx.len() * dim);
        CheckpointStore { dim, policy, thin, n, idx, buf, drop_scratch: Vec::new(), peak_bytes }
    }

    /// Record the next state (index = number of states recorded so far).
    /// Stores or thins per the policy; the budget invariant holds before
    /// and after every push.
    pub fn push(&mut self, z: &[f32]) {
        if self.dim == 0 {
            debug_assert!(!z.is_empty(), "checkpoint state must be non-empty");
            self.dim = z.len();
            self.thin = Thinner::new(self.policy, self.dim);
        }
        debug_assert_eq!(z.len(), self.dim);
        let i = self.n;
        self.n += 1;

        let mut drops = std::mem::take(&mut self.drop_scratch);
        self.thin.plan_push(&self.idx, &mut drops);
        if !drops.is_empty() {
            let dim = self.dim;
            let (idx, buf) = (&mut self.idx, &mut self.buf);
            let w = compact_drops(idx.len(), &drops, |r, dst| {
                if let Some(w) = dst {
                    if w != r {
                        idx[w] = idx[r];
                        buf.copy_within(r * dim..(r + 1) * dim, w * dim);
                    }
                }
            });
            idx.truncate(w);
            buf.truncate(w * dim);
        }
        self.drop_scratch = drops;

        self.idx.push(i);
        self.buf.extend_from_slice(z);
        self.peak_bytes = self.peak_bytes.max(self.bytes());
    }

    /// Total states recorded (stored or thinned) — `N_t + 1` after a solve.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Anchors currently held.
    pub fn n_stored(&self) -> usize {
        self.idx.len()
    }

    /// State `k` if it is stored (`None` means it was thinned — fetch it
    /// through a [`SegmentCache`] instead).
    pub fn stored(&self, k: usize) -> Option<&[f32]> {
        if k >= self.n {
            return None;
        }
        let p = anchor_pos(self.policy, &self.idx, k)?;
        Some(&self.buf[p * self.dim..(p + 1) * self.dim])
    }

    /// The final recorded state — always stored (the tail anchor); `None`
    /// only for an empty store.
    pub fn last(&self) -> Option<&[f32]> {
        let p = self.idx.len().checked_sub(1)?;
        Some(&self.buf[p * self.dim..(p + 1) * self.dim])
    }

    /// Greatest stored index `≤ k` (index 0 is always stored).
    pub fn anchor_at_or_before(&self, k: usize) -> usize {
        anchor_floor(&self.idx, k)
    }

    /// Bytes currently held by stored anchor states.
    pub fn bytes(&self) -> usize {
        self.idx.len() * self.dim * std::mem::size_of::<f32>()
    }

    /// High-water mark of [`Self::bytes`] over the store's lifetime — the
    /// quantity a budget must bound *mid-solve*.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn policy(&self) -> CkptPolicy {
        self.policy
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Clone of the thinning state (for exporting per-track stores).
    pub fn thinner(&self) -> Thinner {
        self.thin.clone()
    }
}

/// Read access to sparse anchors, abstract over where they live: the scalar
/// [`CheckpointStore`] owns its arena; a batched track's anchors live in the
/// shared [`BatchTrajectory`](crate::ode::BatchTrajectory) arena. `Copy`
/// receivers keep the returned borrows tied to the underlying storage, not
/// to a local handle.
pub trait AnchorSource<'a>: Copy {
    fn dim(self) -> usize;
    /// State `k` if stored.
    fn stored(self, k: usize) -> Option<&'a [f32]>;
    /// Greatest stored index `≤ k`.
    fn anchor_at_or_before(self, k: usize) -> usize;
}

impl<'a> AnchorSource<'a> for &'a CheckpointStore {
    fn dim(self) -> usize {
        CheckpointStore::dim(self)
    }
    fn stored(self, k: usize) -> Option<&'a [f32]> {
        CheckpointStore::stored(self, k)
    }
    fn anchor_at_or_before(self, k: usize) -> usize {
        CheckpointStore::anchor_at_or_before(self, k)
    }
}

/// One-segment replay cache for reverse sweeps over a (possibly thinned)
/// store.
///
/// `state(k)` returns the stored anchor when one exists; otherwise it
/// replays forward from the nearest anchor `a ≤ k` with the recorded
/// `(ts, hs)` — bit-identical to the forward pass (see module docs) — and
/// caches the whole segment `a+1 ..= k`. A reverse sweep (`k`, `k−1`, …)
/// therefore replays each segment **once**: amortized one extra forward
/// step per dropped state. Replay `f` evaluations accumulate in
/// [`Self::nfe_replay`]; FSAL tableaus chain stage 0 across replayed steps
/// exactly like the forward loop, so the replay cost matches the forward
/// cost profile.
///
/// Transient memory: the cache holds one full inter-anchor segment —
/// `O(stride × D)` bytes, i.e. up to the states the store thinned away
/// from that segment (the classic checkpoint/recompute buffer; metered by
/// [`Self::peak_bytes`]). Bounding this *below* one segment requires
/// multi-level / recursive checkpointing (treeverse-style), which is
/// follow-on headroom — see ROADMAP.
#[derive(Debug, Default)]
pub struct SegmentCache {
    /// Cached replayed states for indices `lo .. lo + count`, flat.
    buf: Vec<f32>,
    lo: usize,
    count: usize,
    /// Running replay state + scratch (no allocation after warm-up).
    z: Vec<f32>,
    z_next: Vec<f32>,
    k0: Vec<f32>,
    scratch: StepScratch,
    peak_bytes: usize,
    /// Total `f` evaluations spent replaying dropped states.
    pub nfe_replay: usize,
}

impl SegmentCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// High-water mark of the replay buffer — the backward pass's transient
    /// segment memory (`O(stride × D)`), on top of the store's budget.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Fetch state `k`: the stored anchor, the cached replay, or a fresh
    /// segment replay from the nearest anchor. `ts`/`hs` are the trajectory
    /// spine (`ts.len() == hs.len() + 1`); `k` must be a recorded state
    /// index.
    pub fn state<'a, F, S>(
        &'a mut self,
        f: &F,
        tab: &Tableau,
        ts: &[f64],
        hs: &[f64],
        src: S,
        k: usize,
    ) -> &'a [f32]
    where
        F: OdeFunc + ?Sized,
        S: AnchorSource<'a>,
    {
        if let Some(z) = src.stored(k) {
            return z;
        }
        let dim = src.dim();
        if !(self.lo <= k && k < self.lo + self.count) {
            let a = src.anchor_at_or_before(k);
            let za = src.stored(a).expect("anchor_at_or_before returned an unstored index");
            self.buf.clear();
            self.lo = a + 1;
            self.count = 0;
            self.z.clear();
            self.z.extend_from_slice(za);
            self.z_next.resize(dim, 0.0);
            self.k0.resize(dim, 0.0);
            let mut k0_valid = false;
            for j in a..k {
                // Error-norm tolerances do not influence the propagated
                // state; pass arbitrary finite values.
                let out = rk_step(
                    f,
                    tab,
                    ts[j],
                    hs[j],
                    &self.z,
                    if k0_valid { Some(&self.k0[..]) } else { None },
                    1.0,
                    1.0,
                    &mut self.z_next,
                    None,
                    &mut self.scratch,
                );
                self.nfe_replay += out.nfe;
                if tab.fsal {
                    self.k0.copy_from_slice(&self.scratch.ks[tab.stages - 1]);
                    k0_valid = true;
                }
                std::mem::swap(&mut self.z, &mut self.z_next);
                self.buf.extend_from_slice(&self.z);
                self.count += 1;
            }
            self.peak_bytes =
                self.peak_bytes.max(self.buf.len() * std::mem::size_of::<f32>());
        }
        let off = (k - self.lo) * dim;
        &self.buf[off..off + dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::{integrate, tableau, IntegrateOpts};

    fn states_of(n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n).map(|i| vec![i as f32; dim]).collect()
    }

    #[test]
    fn dense_stores_everything() {
        let mut s = CheckpointStore::new(3, CkptPolicy::Dense);
        for z in states_of(10, 3) {
            s.push(&z);
        }
        assert_eq!(s.len(), 10);
        assert_eq!(s.n_stored(), 10);
        for k in 0..10 {
            assert_eq!(s.stored(k).unwrap(), &[k as f32; 3]);
        }
        assert_eq!(s.bytes(), 10 * 3 * 4);
        assert_eq!(s.peak_bytes(), s.bytes());
        assert_eq!(s.last().unwrap(), &[9.0f32; 3]);
    }

    #[test]
    fn every_k_keeps_grid_plus_tail() {
        let mut s = CheckpointStore::new(1, CkptPolicy::EveryK(4));
        for z in states_of(11, 1) {
            s.push(&z);
        }
        // Kept: 0, 4, 8 (grid) + 10 (tail); 1..3, 5..7, 9 thinned.
        for k in [0usize, 4, 8, 10] {
            assert!(s.stored(k).is_some(), "state {k} must be an anchor");
        }
        for k in [1usize, 2, 3, 5, 6, 7, 9] {
            assert!(s.stored(k).is_none(), "state {k} must be thinned");
        }
        assert_eq!(s.anchor_at_or_before(7), 4);
        assert_eq!(s.anchor_at_or_before(4), 4);
        assert_eq!(s.anchor_at_or_before(3), 0);
        assert_eq!(s.last().unwrap(), &[10.0f32]);
    }

    #[test]
    fn budgeted_holds_budget_mid_flight() {
        // Budget for exactly 5 single-f32 states.
        let budget = 5 * 4;
        let mut s = CheckpointStore::new(1, CkptPolicy::Budgeted(budget));
        for (i, z) in states_of(64, 1).into_iter().enumerate() {
            s.push(&z);
            assert!(
                s.bytes() <= budget,
                "after push {i}: {} bytes over the {budget}-byte budget",
                s.bytes()
            );
            assert!(s.stored(0).is_some(), "state 0 must always be stored");
            assert_eq!(s.last().unwrap(), &[i as f32], "tail must always be stored");
        }
        assert!(s.peak_bytes() <= budget);
        // Anchors are evenly spread: every stored non-tail index is a
        // multiple of the final stride.
        let stride = s.thinner().stride();
        assert!(stride >= 16, "64 states / 5 anchors needs stride ≥ 16, got {stride}");
        for &j in &s.idx[..s.idx.len() - 1] {
            assert_eq!(j % stride, 0, "anchor {j} off the stride-{stride} grid");
        }
    }

    #[test]
    fn tiny_budget_degenerates_to_endpoints() {
        let mut s = CheckpointStore::new(4, CkptPolicy::Budgeted(1)); // < one state
        for z in states_of(20, 4) {
            s.push(&z);
        }
        // cap clamps to 2: initial state + tail.
        assert_eq!(s.n_stored(), 2);
        assert!(s.stored(0).is_some());
        assert_eq!(s.last().unwrap(), &[19.0f32; 4]);
    }

    #[test]
    fn replay_is_bit_exact_against_dense() {
        let f = VanDerPol::new(0.7);
        let tab = tableau::dopri5();
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let dense = integrate(&f, 0.0, 4.0, &[1.8, -0.3], tab, &opts).unwrap();
        assert!(dense.len() >= 12, "need enough steps to thin");

        for policy in [
            CkptPolicy::EveryK(4),
            CkptPolicy::Budgeted(dense.store.bytes() / 4),
        ] {
            let mut thin = CheckpointStore::new(2, policy);
            for k in 0..dense.store.len() {
                thin.push(dense.store.stored(k).unwrap());
            }
            assert!(thin.n_stored() < dense.store.n_stored(), "{policy:?} thinned nothing");
            let mut cache = SegmentCache::new();
            // Reverse order — the access pattern of the backward sweep.
            for k in (0..dense.store.len()).rev() {
                let z = cache.state(&f, tab, &dense.ts, &dense.hs, &thin, k);
                assert_eq!(z, dense.store.stored(k).unwrap(), "{policy:?}: state {k}");
            }
            assert!(cache.nfe_replay > 0, "{policy:?}: replay must have evaluated f");
            // Each dropped state is replayed exactly once: replay evals are
            // bounded by one step's stage cost per dropped state.
            let dropped = dense.store.n_stored() - thin.n_stored();
            assert!(
                cache.nfe_replay <= dropped * tab.stages,
                "{policy:?}: {} replay evals for {dropped} dropped states",
                cache.nfe_replay
            );
        }
    }

    #[test]
    fn segment_cache_returns_stored_anchors_without_replay() {
        let f = Linear::new(-0.5, 2);
        let tab = tableau::rk4();
        let traj = integrate(&f, 0.0, 1.0, &[1.0, 2.0], tab, &IntegrateOpts::fixed(0.1)).unwrap();
        let mut cache = SegmentCache::new();
        for k in 0..traj.store.len() {
            let z = cache.state(&f, tab, &traj.ts, &traj.hs, &traj.store, k);
            assert_eq!(z, traj.store.stored(k).unwrap());
        }
        assert_eq!(cache.nfe_replay, 0, "dense store must never replay");
    }

    #[test]
    fn env_budget_parse_and_clamp() {
        // One test for all cases: the process env is shared across threads.
        std::env::set_var("NODAL_CKPT_BUDGET_BYTES", "0");
        assert_eq!(env_budget_bytes(), 0, "0 means unbudgeted");
        std::env::set_var("NODAL_CKPT_BUDGET_BYTES", "7");
        assert_eq!(env_budget_bytes(), BUDGET_MIN_BYTES, "clamps up");
        std::env::set_var("NODAL_CKPT_BUDGET_BYTES", "1048576");
        assert_eq!(env_budget_bytes(), 1 << 20);
        std::env::set_var("NODAL_CKPT_BUDGET_BYTES", "not-a-number");
        assert_eq!(env_budget_bytes(), 0, "unparseable falls back to unbudgeted");
        std::env::remove_var("NODAL_CKPT_BUDGET_BYTES");
        assert_eq!(env_budget_bytes(), 0);
        assert_eq!(CkptPolicy::from_budget(0), CkptPolicy::Dense);
        assert_eq!(CkptPolicy::from_budget(4096), CkptPolicy::Budgeted(4096));
        // The shared clamp rule hand-built configs go through too.
        assert_eq!(clamp_budget(0), 0, "0 = off passes through");
        assert_eq!(clamp_budget(1), BUDGET_MIN_BYTES);
        assert_eq!(clamp_budget(usize::MAX), BUDGET_MAX_BYTES);
        assert_eq!(clamp_budget(4096), 4096);
    }
}
