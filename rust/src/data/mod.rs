//! Synthetic dataset generators — the substitutes for the paper's CIFAR /
//! Mujoco / three-body workloads (DESIGN.md §6).

pub mod images;
pub mod spirals;
pub mod threebody;
pub mod timeseries;

pub use images::ImageDataset;
pub use spirals::SpiralDataset;
pub use threebody::ThreeBodyDataset;
pub use timeseries::TimeSeriesDataset;

use crate::runtime::hlo_model::Target;

/// A labelled classification dataset with train/test splits, gatherable into
/// fixed-size batches for the AOT executables.
pub struct Dataset {
    pub dim_in: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.train_y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.train_y.is_empty()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    fn gather_from(&self, x: &[f32], y: &[i32], ids: &[usize]) -> (Vec<f32>, Target) {
        let d = self.dim_in;
        let mut bx = Vec::with_capacity(ids.len() * d);
        let mut by = Vec::with_capacity(ids.len());
        for &i in ids {
            bx.extend_from_slice(&x[i * d..(i + 1) * d]);
            by.push(y[i]);
        }
        (bx, Target::Classes(by))
    }

    /// Gather a train batch by indices.
    pub fn gather(&self, ids: &[usize]) -> (Vec<f32>, Target) {
        self.gather_from(&self.train_x, &self.train_y, ids)
    }

    /// Gather a test batch by indices.
    pub fn gather_test(&self, ids: &[usize]) -> (Vec<f32>, Target) {
        self.gather_from(&self.test_x, &self.test_y, ids)
    }
}
