//! Three-body dataset (paper Sec 4.4, Table 5): simulate a 3-body system
//! with *unequal masses* and *arbitrary initial conditions* using the
//! analytic Newtonian dynamics at tight tolerance; training data is the
//! trajectory over `[0, 1]` year, evaluation over `[0, 2]` years, sampled at
//! 1000 points per year as in the paper's Appendix D.4.

use crate::ode::analytic::ThreeBody;
use crate::ode::dense::DenseOutput;
use crate::ode::{integrate, tableau, IntegrateOpts};
use crate::util::Pcg64;

/// A simulated three-body system with its sampled trajectory.
pub struct ThreeBodyDataset {
    /// Ground-truth masses (unequal, hidden from the learners).
    pub masses: [f32; 3],
    /// Initial full state (positions + velocities, dim 18).
    pub z0: Vec<f32>,
    /// Sample times over `[0, 2·t_train]`, uniform, `2 × n_per_year` points.
    pub times: Vec<f64>,
    /// Full states at `times` (`len × 18`).
    pub states: Vec<Vec<f32>>,
    /// End of the training range (1 year).
    pub t_train: f64,
}

impl ThreeBodyDataset {
    /// Simulate one system. Initial conditions are drawn near a hierarchical
    /// configuration so the system stays bound over 2 years (chaotic but not
    /// immediately ejecting — mirrors the paper's simulated systems).
    pub fn generate(seed: u64, n_per_year: usize) -> Self {
        let mut rng = Pcg64::new(seed, 40);
        // Unequal masses around solar scale.
        let masses = [
            1.0 + 0.4 * rng.normal_f32().abs(),
            0.5 + 0.3 * rng.uniform_f32(),
            0.3 + 0.2 * rng.uniform_f32(),
        ];
        // Hierarchical: body 1 near origin; bodies 2, 3 on perturbed orbits.
        let mut z0 = vec![0.0f32; 18];
        let g = crate::ode::analytic::three_body::G;
        // body 2 at ~1 AU
        let r2 = 0.9 + 0.3 * rng.uniform_f32();
        let ang2 = rng.uniform() * std::f64::consts::TAU;
        z0[3] = r2 * ang2.cos() as f32;
        z0[4] = r2 * ang2.sin() as f32;
        z0[5] = 0.1 * rng.normal_f32();
        let v2 = (g * (masses[0] + masses[1]) / r2).sqrt() * (0.9 + 0.2 * rng.uniform_f32());
        z0[12] = -v2 * ang2.sin() as f32;
        z0[13] = v2 * ang2.cos() as f32;
        z0[14] = 0.05 * rng.normal_f32();
        // body 3 at ~2 AU
        let r3 = 1.8 + 0.5 * rng.uniform_f32();
        let ang3 = rng.uniform() * std::f64::consts::TAU;
        z0[6] = r3 * ang3.cos() as f32;
        z0[7] = r3 * ang3.sin() as f32;
        z0[8] = 0.1 * rng.normal_f32();
        let v3 = (g * masses[0] / r3).sqrt() * (0.9 + 0.2 * rng.uniform_f32());
        z0[15] = -v3 * ang3.sin() as f32;
        z0[16] = v3 * ang3.cos() as f32;
        z0[17] = 0.05 * rng.normal_f32();

        let t_train = 1.0;
        let t_end = 2.0 * t_train;
        let f = ThreeBody::new(masses);
        let traj = integrate(
            &f,
            0.0,
            t_end,
            &z0,
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-9, 1e-9),
        )
        .expect("ground-truth three-body integration failed");
        let dense = DenseOutput::new(&f, &traj);
        let n = 2 * n_per_year;
        let times: Vec<f64> = (0..=n).map(|i| t_end * i as f64 / n as f64).collect();
        let states: Vec<Vec<f32>> = times.iter().map(|&t| dense.eval(t)).collect();

        ThreeBodyDataset { masses, z0, times, states, t_train }
    }

    /// Index of the last training sample (t <= 1 year).
    pub fn train_end(&self) -> usize {
        self.times.iter().position(|&t| t > self.t_train).unwrap_or(self.times.len()) - 1
    }

    /// Positions (first 9 dims) at sample `i`.
    pub fn positions(&self, i: usize) -> &[f32] {
        &self.states[i][..9]
    }

    /// Mean squared position error of predicted positions over a time range
    /// `[i0, i1)` against the ground truth.
    pub fn position_mse(&self, preds: &[Vec<f32>], i0: usize) -> f64 {
        let mut acc = 0.0;
        let mut n = 0usize;
        for (k, p) in preds.iter().enumerate() {
            let truth = self.positions(i0 + k);
            for (a, b) in p.iter().zip(truth) {
                let d = (*a - *b) as f64;
                acc += d * d;
            }
            n += truth.len();
        }
        acc / n.max(1) as f64
    }

    /// LSTM training sequences: sliding windows of `seq_len` positions with
    /// next-position targets, over the training year, advancing by `stride`.
    pub fn lstm_windows(&self, seq_len: usize, stride: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let end = self.train_end();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut i = 0;
        while i + seq_len + 1 <= end {
            let mut x = Vec::with_capacity(seq_len * 9);
            let mut y = Vec::with_capacity(seq_len * 9);
            for k in 0..seq_len {
                x.extend_from_slice(self.positions(i + k));
                y.extend_from_slice(self.positions(i + k + 1));
            }
            xs.push(x);
            ys.push(y);
            i += stride.max(1);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ThreeBodyDataset {
        ThreeBodyDataset::generate(1, 100)
    }

    #[test]
    fn shapes_and_ranges() {
        let d = small();
        assert_eq!(d.times.len(), 201);
        assert_eq!(d.states.len(), 201);
        assert_eq!(d.states[0].len(), 18);
        assert_eq!(d.times[0], 0.0);
        assert!((d.times[200] - 2.0).abs() < 1e-12);
        assert!(d.masses[0] != d.masses[1] && d.masses[1] != d.masses[2]);
    }

    #[test]
    fn initial_state_matches_first_sample() {
        let d = small();
        for (a, b) in d.z0.iter().zip(&d.states[0]) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn train_split_at_one_year() {
        let d = small();
        let e = d.train_end();
        assert!(d.times[e] <= 1.0 + 1e-9);
        assert!(d.times[e + 1] > 1.0);
    }

    #[test]
    fn system_stays_bounded() {
        let d = small();
        for s in &d.states {
            for v in &s[..9] {
                assert!(v.abs() < 50.0, "system ejected: {v}");
            }
        }
    }

    #[test]
    fn lstm_windows_shapes() {
        let d = small();
        let (xs, ys) = d.lstm_windows(20, 10);
        assert!(!xs.is_empty());
        assert_eq!(xs[0].len(), 20 * 9);
        assert_eq!(ys[0].len(), 20 * 9);
        // target is shifted input
        assert_eq!(&xs[0][9..18], d.positions(1));
        assert_eq!(&ys[0][0..9], d.positions(1));
    }

    #[test]
    fn position_mse_zero_for_truth() {
        let d = small();
        let preds: Vec<Vec<f32>> = (0..5).map(|i| d.positions(i).to_vec()).collect();
        assert!(d.position_mse(&preds, 0) < 1e-12);
    }

    #[test]
    fn different_seeds_different_systems() {
        let a = ThreeBodyDataset::generate(1, 10);
        let b = ThreeBodyDataset::generate(2, 10);
        assert_ne!(a.masses, b.masses);
    }
}
