//! Irregularly-sampled time-series workload — the Mujoco/Latent-ODE
//! substitute (paper Sec 4.3, Table 4; DESIGN.md §6).
//!
//! Latent dynamics: two coupled damped harmonic oscillators (4-d latent
//! state); observations are a random linear mixing of the latent state into
//! `OBS_DIM = 4` channels. Observation times are drawn from a Poisson-like
//! process (uniform order statistics). Sequences come in *groups* that share
//! one irregular grid — grids differ across groups — so the AOT executables
//! can batch a group while the task retains arbitrary time gaps.

use crate::util::Pcg64;

pub const OBS_DIM: usize = 4;
/// Observations per sequence (= the AOT `ts_*` models' seq_len).
pub const SEQ_OBS: usize = 40;
/// Observations consumed by the NODE encoder.
pub const ENC_WINDOW: usize = 5;

/// A group of sequences sharing one irregular observation grid.
#[derive(Debug, Clone)]
pub struct Group {
    /// Observation times, strictly increasing in `[0, t_max]` (len SEQ_OBS).
    pub times: Vec<f64>,
    /// Per-sequence observed values, each `SEQ_OBS × OBS_DIM` row-major.
    pub values: Vec<Vec<f32>>,
}

/// Train/test collection.
pub struct TimeSeriesDataset {
    pub train: Vec<Group>,
    pub test: Vec<Group>,
    pub t_max: f64,
}

fn irregular_grid(rng: &mut Pcg64, t_max: f64) -> Vec<f64> {
    let mut times: Vec<f64> = (0..SEQ_OBS).map(|_| rng.uniform() * t_max).collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for i in 1..times.len() {
        if times[i] <= times[i - 1] {
            times[i] = times[i - 1] + 1e-4;
        }
    }
    times
}

fn simulate_on(times: &[f64], rng: &mut Pcg64) -> Vec<f32> {
    let w1 = 2.0 + rng.uniform() * 2.0;
    let w2 = 3.0 + rng.uniform() * 3.0;
    let zeta = 0.05 + 0.1 * rng.uniform();
    let coupling = 0.4 * rng.uniform();
    let a1 = 0.5 + rng.uniform();
    let a2 = 0.5 + rng.uniform();
    let p1 = rng.uniform() * std::f64::consts::TAU;
    let p2 = rng.uniform() * std::f64::consts::TAU;
    let mix: Vec<f64> = (0..16).map(|_| rng.normal() * 0.7).collect();

    let mut values = Vec::with_capacity(times.len() * OBS_DIM);
    for &t in times {
        let e = (-zeta * t).exp();
        let th1 = w1 * t + p1 + coupling * (w2 * t + p2).sin();
        let th2 = w2 * t + p2 + coupling * (w1 * t + p1).sin();
        let latent = [
            a1 * e * th1.sin(),
            a1 * e * th1.cos(),
            a2 * e * th2.sin(),
            a2 * e * th2.cos(),
        ];
        for r in 0..OBS_DIM {
            let mut v = 0.0;
            for (c, l) in latent.iter().enumerate() {
                v += mix[r * 4 + c] * l;
            }
            values.push(v as f32);
        }
    }
    values
}

impl TimeSeriesDataset {
    /// `n_train`/`n_test` groups of `group_size` sequences each.
    pub fn generate(
        n_train: usize,
        n_test: usize,
        group_size: usize,
        t_max: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg64::new(seed, 30);
        let mut make = |n: usize| -> Vec<Group> {
            (0..n)
                .map(|_| {
                    let times = irregular_grid(&mut rng, t_max);
                    let values =
                        (0..group_size).map(|_| simulate_on(&times, &mut rng)).collect();
                    Group { times, values }
                })
                .collect()
        };
        let train = make(n_train);
        let test = make(n_test);
        TimeSeriesDataset { train, test, t_max }
    }

    /// Keep only `pct`% of the training groups (Table 4's x-axis).
    pub fn subset(&self, pct: usize) -> Vec<&Group> {
        let n = (self.train.len() * pct / 100).max(1);
        self.train.iter().take(n).collect()
    }
}

impl Group {
    pub fn batch(&self) -> usize {
        self.values.len()
    }

    /// Encoder input for the whole group: `[B, ENC_WINDOW × OBS_DIM]` flat.
    pub fn encoder_input(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.batch() * ENC_WINDOW * OBS_DIM);
        for v in &self.values {
            out.extend_from_slice(&v[..ENC_WINDOW * OBS_DIM]);
        }
        out
    }

    /// Integration grid: anchor at the last encoder observation, then every
    /// later observation time.
    pub fn target_times(&self) -> &[f64] {
        &self.times[ENC_WINDOW - 1..]
    }

    /// Batched target at observation `k` (0-based among targets):
    /// `[B × OBS_DIM]` values at `times[ENC_WINDOW + k]`.
    pub fn target_at(&self, k: usize) -> Vec<f32> {
        let idx = ENC_WINDOW + k;
        let mut out = Vec::with_capacity(self.batch() * OBS_DIM);
        for v in &self.values {
            out.extend_from_slice(&v[idx * OBS_DIM..(idx + 1) * OBS_DIM]);
        }
        out
    }

    /// Number of target observations.
    pub fn n_targets(&self) -> usize {
        SEQ_OBS - ENC_WINDOW
    }

    /// RNN input encoding `[B, T, OBS_DIM+1]`: per-step value + Δt.
    pub fn rnn_inputs(&self) -> Vec<f32> {
        let b = self.batch();
        let mut out = Vec::with_capacity(b * SEQ_OBS * (OBS_DIM + 1));
        for v in &self.values {
            let mut prev_t = 0.0f64;
            for (i, &t) in self.times.iter().enumerate() {
                out.extend_from_slice(&v[i * OBS_DIM..(i + 1) * OBS_DIM]);
                out.push((t - prev_t) as f32);
                prev_t = t;
            }
        }
        out
    }

    /// RNN targets `[B, T, OBS_DIM]`: the next observation (last repeats).
    pub fn rnn_targets(&self) -> Vec<f32> {
        let b = self.batch();
        let n = self.times.len();
        let mut out = Vec::with_capacity(b * n * OBS_DIM);
        for v in &self.values {
            for i in 0..n {
                let j = (i + 1).min(n - 1);
                out.extend_from_slice(&v[j * OBS_DIM..(j + 1) * OBS_DIM]);
            }
        }
        out
    }

    /// Per-step-ahead MSE of RNN predictions against `rnn_targets`, counting
    /// only the interpolation region (after the encoder window) for parity
    /// with the NODE evaluation.
    pub fn rnn_interp_mse(&self, preds: &[f32]) -> f64 {
        let b = self.batch();
        let n = self.times.len();
        let targets = self.rnn_targets();
        let mut acc = 0.0;
        let mut cnt = 0usize;
        for s in 0..b {
            for i in ENC_WINDOW..n - 1 {
                for c in 0..OBS_DIM {
                    let idx = (s * n + i) * OBS_DIM + c;
                    let d = (preds[idx] - targets[idx]) as f64;
                    acc += d * d;
                    cnt += 1;
                }
            }
        }
        acc / cnt.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> TimeSeriesDataset {
        TimeSeriesDataset::generate(4, 2, 8, 5.0, 1)
    }

    #[test]
    fn shapes() {
        let d = ds();
        assert_eq!(d.train.len(), 4);
        for g in &d.train {
            assert_eq!(g.times.len(), SEQ_OBS);
            assert_eq!(g.batch(), 8);
            for v in &g.values {
                assert_eq!(v.len(), SEQ_OBS * OBS_DIM);
            }
        }
    }

    #[test]
    fn times_strictly_increasing_and_shared_within_group() {
        let d = ds();
        for g in &d.train {
            for w in g.times.windows(2) {
                assert!(w[1] > w[0]);
            }
        }
        // …but different across groups.
        assert_ne!(d.train[0].times, d.train[1].times);
    }

    #[test]
    fn irregular_gaps() {
        let d = ds();
        let gaps: Vec<f64> = d.train[0].times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        assert!(var > 1e-4, "sampling looks regular: var {var}");
    }

    #[test]
    fn encoder_and_target_shapes() {
        let d = ds();
        let g = &d.train[0];
        assert_eq!(g.encoder_input().len(), 8 * ENC_WINDOW * OBS_DIM);
        assert_eq!(g.target_times().len(), SEQ_OBS - ENC_WINDOW + 1);
        assert_eq!(g.n_targets(), SEQ_OBS - ENC_WINDOW);
        assert_eq!(g.target_at(0).len(), 8 * OBS_DIM);
        // target 0 is the observation right after the encoder window
        assert_eq!(g.target_at(0)[..4], g.values[0][ENC_WINDOW * 4..ENC_WINDOW * 4 + 4]);
    }

    #[test]
    fn rnn_shapes_and_dt() {
        let d = ds();
        let g = &d.train[0];
        assert_eq!(g.rnn_inputs().len(), 8 * SEQ_OBS * (OBS_DIM + 1));
        assert_eq!(g.rnn_targets().len(), 8 * SEQ_OBS * OBS_DIM);
        // first Δt equals times[0] for every sequence
        let inp = g.rnn_inputs();
        let stride = SEQ_OBS * (OBS_DIM + 1);
        for s in 0..8 {
            assert!((inp[s * stride + OBS_DIM] as f64 - g.times[0]).abs() < 1e-5);
        }
    }

    #[test]
    fn interp_mse_zero_for_perfect_preds() {
        let d = ds();
        let g = &d.train[0];
        let preds = g.rnn_targets();
        assert!(g.rnn_interp_mse(&preds) < 1e-12);
    }

    #[test]
    fn subsets() {
        let d = TimeSeriesDataset::generate(10, 0, 2, 5.0, 2);
        assert_eq!(d.subset(10).len(), 1);
        assert_eq!(d.subset(50).len(), 5);
    }

    #[test]
    fn values_bounded() {
        let d = ds();
        for g in &d.train {
            for v in &g.values {
                assert!(v.iter().all(|x| x.abs() < 20.0));
            }
        }
    }
}
