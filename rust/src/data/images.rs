//! Procedural 16×16 image classification — the CIFAR substitute
//! (DESIGN.md §6). Ten pattern classes with random translation, intensity
//! jitter, and pixel noise, so the task needs real spatial features but
//! trains in minutes on CPU.

use super::Dataset;
use crate::util::Pcg64;

pub const SIDE: usize = 16;
pub const CLASSES: usize = 10;

/// Generator for the 10-class shapes/texture dataset.
pub struct ImageDataset;

fn paint(class: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut img = vec![0.0f32; SIDE * SIDE];
    let cx = 8 + rng.below(5) as isize - 2;
    let cy = 8 + rng.below(5) as isize - 2;
    let amp = 0.7 + 0.3 * rng.uniform_f32();
    let mut set = |x: isize, y: isize, v: f32| {
        if (0..SIDE as isize).contains(&x) && (0..SIDE as isize).contains(&y) {
            img[(y as usize) * SIDE + x as usize] += v;
        }
    };
    match class {
        0 => {
            // filled circle r=4
            for y in -5..=5 {
                for x in -5..=5 {
                    if x * x + y * y <= 16 {
                        set(cx + x, cy + y, amp);
                    }
                }
            }
        }
        1 => {
            // hollow square 9x9
            for k in -4..=4 {
                set(cx + k, cy - 4, amp);
                set(cx + k, cy + 4, amp);
                set(cx - 4, cy + k, amp);
                set(cx + 4, cy + k, amp);
            }
        }
        2 => {
            // plus / cross
            for k in -5..=5 {
                set(cx + k, cy, amp);
                set(cx, cy + k, amp);
            }
        }
        3 => {
            // horizontal stripes period 4
            for y in 0..SIDE as isize {
                if (y / 2) % 2 == 0 {
                    for x in 0..SIDE as isize {
                        set(x, y, amp * 0.8);
                    }
                }
            }
        }
        4 => {
            // vertical stripes period 4
            for x in 0..SIDE as isize {
                if (x / 2) % 2 == 0 {
                    for y in 0..SIDE as isize {
                        set(x, y, amp * 0.8);
                    }
                }
            }
        }
        5 => {
            // main diagonal band
            for y in 0..SIDE as isize {
                for x in 0..SIDE as isize {
                    if (x - y).abs() <= 1 {
                        set(x, y, amp);
                    }
                }
            }
        }
        6 => {
            // checkerboard 4x4 blocks
            for y in 0..SIDE as isize {
                for x in 0..SIDE as isize {
                    if ((x / 4) + (y / 4)) % 2 == 0 {
                        set(x, y, amp * 0.7);
                    }
                }
            }
        }
        7 => {
            // dot grid period 4
            for y in (1..SIDE as isize).step_by(4) {
                for x in (1..SIDE as isize).step_by(4) {
                    set(x, y, amp);
                    set(x + 1, y, amp);
                    set(x, y + 1, amp);
                    set(x + 1, y + 1, amp);
                }
            }
        }
        8 => {
            // ring (hollow circle)
            for y in -6..=6 {
                for x in -6..=6isize {
                    let r2 = x * x + y * y;
                    if (16..=30).contains(&r2) {
                        set(cx + x, cy + y, amp);
                    }
                }
            }
        }
        9 => {
            // filled triangle
            for y in 0..8isize {
                for x in -y..=y {
                    set(cx + x, cy - 4 + y, amp);
                }
            }
        }
        _ => unreachable!(),
    }
    img
}

impl ImageDataset {
    /// Generate `n_train` + `n_test` images with pixel noise `noise`.
    pub fn generate(n_train: usize, n_test: usize, noise: f32, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed, 20);
        let mut make = |n: usize| {
            let mut xs = Vec::with_capacity(n * SIDE * SIDE);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let class = i % CLASSES;
                let mut img = paint(class, &mut rng);
                for p in img.iter_mut() {
                    *p = (*p + rng.normal_f32() * noise).clamp(-0.5, 1.5);
                }
                xs.extend_from_slice(&img);
                ys.push(class as i32);
            }
            (xs, ys)
        };
        let (train_x, train_y) = make(n_train);
        let (test_x, test_y) = make(n_test);
        Dataset { dim_in: SIDE * SIDE, classes: CLASSES, train_x, train_y, test_x, test_y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let d = ImageDataset::generate(100, 50, 0.05, 1);
        assert_eq!(d.dim_in, 256);
        assert_eq!(d.train_x.len(), 100 * 256);
        for c in 0..CLASSES as i32 {
            assert_eq!(d.train_y.iter().filter(|&&y| y == c).count(), 10);
        }
    }

    #[test]
    fn all_classes_render_nonzero_patterns() {
        let mut rng = Pcg64::seed(2);
        for c in 0..CLASSES {
            let img = paint(c, &mut rng);
            let energy: f32 = img.iter().map(|v| v.abs()).sum();
            assert!(energy > 1.0, "class {c} renders empty image");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean intra-class distance should be well below inter-class distance
        // for noiseless canonical images.
        let mut rng = Pcg64::seed(3);
        let protos: Vec<Vec<f32>> = (0..CLASSES).map(|c| paint(c, &mut rng)).collect();
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        for i in 0..CLASSES {
            for j in (i + 1)..CLASSES {
                assert!(
                    dist(&protos[i], &protos[j]) > 1.0,
                    "classes {i} and {j} are nearly identical"
                );
            }
        }
    }

    #[test]
    fn pixels_bounded() {
        let d = ImageDataset::generate(30, 0, 0.1, 4);
        assert!(d.train_x.iter().all(|v| (-0.5..=1.5).contains(v)));
    }

    #[test]
    fn deterministic() {
        let a = ImageDataset::generate(10, 5, 0.05, 9);
        let b = ImageDataset::generate(10, 5, 0.05, 9);
        assert_eq!(a.train_x, b.train_x);
    }
}
