//! Two-class interleaved spirals — the 2-D sanity workload for the
//! quickstart example and fast trainer tests.

use super::Dataset;
use crate::util::Pcg64;

/// Generator for the two-spirals task.
pub struct SpiralDataset;

impl SpiralDataset {
    /// `n_train`/`n_test` points per split, Gaussian noise `noise`.
    pub fn generate(n_train: usize, n_test: usize, noise: f32, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed, 10);
        let mut make = |n: usize| {
            let mut xs = Vec::with_capacity(n * 2);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let class = (i % 2) as i32;
                let t = 0.5 + 3.0 * rng.uniform(); // radians along the arm
                let r = 0.25 * t;
                let phase = if class == 0 { 0.0 } else { std::f64::consts::PI };
                let x = (r * (t + phase).cos()) as f32 + rng.normal_f32() * noise;
                let y = (r * (t + phase).sin()) as f32 + rng.normal_f32() * noise;
                xs.push(x);
                xs.push(y);
                ys.push(class);
            }
            (xs, ys)
        };
        let (train_x, train_y) = make(n_train);
        let (test_x, test_y) = make(n_test);
        Dataset { dim_in: 2, classes: 2, train_x, train_y, test_x, test_y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_labels() {
        let d = SpiralDataset::generate(100, 40, 0.02, 1);
        assert_eq!(d.len(), 100);
        assert_eq!(d.test_len(), 40);
        assert_eq!(d.train_x.len(), 200);
        assert!(d.train_y.iter().all(|&y| y == 0 || y == 1));
        // balanced classes
        let ones: usize = d.train_y.iter().filter(|&&y| y == 1).count();
        assert_eq!(ones, 50);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SpiralDataset::generate(10, 5, 0.01, 3);
        let b = SpiralDataset::generate(10, 5, 0.01, 3);
        assert_eq!(a.train_x, b.train_x);
        let c = SpiralDataset::generate(10, 5, 0.01, 4);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn classes_are_separated_at_zero_noise() {
        // With zero noise, nearest-neighbor across classes should not be
        // trivially overlapping at the same angle.
        let d = SpiralDataset::generate(200, 10, 0.0, 5);
        for i in 0..d.len() {
            let (x, y) = (d.train_x[2 * i], d.train_x[2 * i + 1]);
            assert!(x.is_finite() && y.is_finite());
            assert!(x.abs() < 1.2 && y.abs() < 1.2);
        }
    }

    #[test]
    fn gather_batches() {
        let d = SpiralDataset::generate(10, 10, 0.01, 7);
        let (x, y) = d.gather(&[0, 3, 5]);
        assert_eq!(x.len(), 6);
        match y {
            crate::runtime::hlo_model::Target::Classes(c) => assert_eq!(c.len(), 3),
            _ => panic!(),
        }
    }
}
