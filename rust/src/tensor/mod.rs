//! Flat f32 vector math used by the solver hot loop.
//!
//! ODE states, adjoint variables and parameter gradients are flat `[f32]`
//! buffers (batch dimensions are flattened by the artifact contract, see
//! DESIGN.md §5). The stage arithmetic of a Runge–Kutta step is a handful of
//! axpy/scale/norm operations over those buffers; everything heavy (the
//! dynamics `f` itself) runs inside XLA. These helpers are written to
//! auto-vectorize and to allow buffer reuse from the integrator's arena.

/// y += a * x
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * *xi;
    }
}

/// out = z  (copy)
#[inline]
pub fn copy(z: &[f32], out: &mut [f32]) {
    out.copy_from_slice(z);
}

/// x *= a
#[inline]
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// x = 0
#[inline]
pub fn zero(x: &mut [f32]) {
    x.fill(0.0);
}

/// out = z + h * sum_j coeff[j] * ks[j]   (the RK update / error combination)
///
/// `ks` are the stage derivatives; entries with zero coefficient are skipped.
/// The coefficient product is formed in f64 and cast once — the *same*
/// rounding as the stage-u path in `rk_step`, which makes the FSAL identity
/// (last stage input == next step state) bit-exact.
#[inline]
pub fn combine(z: &[f32], h: f64, coeff: &[f64], ks: &[Vec<f32>], out: &mut [f32]) {
    out.copy_from_slice(z);
    for (c, k) in coeff.iter().zip(ks) {
        if *c != 0.0 {
            axpy((h * *c) as f32, k, out);
        }
    }
}

/// Weighted RMS norm used by the adaptive step controller:
/// `sqrt(mean_i (e_i / (atol + rtol * max(|z0_i|, |z1_i|)))^2)`.
///
/// An accepted step has `wrms <= 1`.
#[inline]
pub fn wrms_norm(err: &[f32], z0: &[f32], z1: &[f32], atol: f64, rtol: f64) -> f64 {
    debug_assert_eq!(err.len(), z0.len());
    debug_assert_eq!(err.len(), z1.len());
    if err.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for i in 0..err.len() {
        let sc = atol + rtol * (z0[i].abs().max(z1[i].abs())) as f64;
        let r = err[i] as f64 / sc;
        acc += r * r;
    }
    (acc / err.len() as f64).sqrt()
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Dot product in f64 accumulation.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum()
}

/// Max |x_i - y_i|.
#[inline]
pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
}

/// Mean squared error between two flat buffers.
#[inline]
pub fn mse(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return 0.0;
    }
    x.iter()
        .zip(y)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum::<f64>()
        / x.len() as f64
}

/// True iff every element is finite.
#[inline]
pub fn all_finite(x: &[f32]) -> bool {
    x.iter().all(|v| v.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }

    #[test]
    fn combine_matches_manual() {
        let z = [1.0f32, -1.0];
        let ks = vec![vec![2.0f32, 0.5], vec![-1.0, 4.0]];
        let mut out = [0.0f32; 2];
        combine(&z, 0.1f64, &[0.5, 0.5], &ks, &mut out);
        assert!((out[0] - (1.0 + 0.1 * 0.5 * (2.0 - 1.0))).abs() < 1e-6);
        assert!((out[1] - (-1.0 + 0.1 * 0.5 * (0.5 + 4.0))).abs() < 1e-6);
    }

    #[test]
    fn combine_skips_zero_coefficients() {
        let z = [1.0f32];
        let ks = vec![vec![f32::NAN], vec![2.0f32]];
        let mut out = [0.0f32];
        // coefficient 0 for the NaN stage: must be skipped, not multiplied.
        combine(&z, 1.0f64, &[0.0, 1.0], &ks, &mut out);
        assert_eq!(out[0], 3.0);
    }

    #[test]
    fn wrms_accept_boundary() {
        // err exactly atol everywhere, z = 0 => wrms = 1.
        let err = [1e-6f32; 8];
        let z = [0.0f32; 8];
        let n = wrms_norm(&err, &z, &z, 1e-6, 0.0);
        assert!((n - 1.0).abs() < 1e-3);
    }

    #[test]
    fn wrms_scales_with_rtol() {
        let err = [0.01f32; 4];
        let z = [10.0f32; 4];
        // scale = rtol * 10 = 0.01 -> wrms 1.
        let n = wrms_norm(&err, &z, &z, 0.0, 1e-3);
        assert!((n - 1.0).abs() < 1e-3);
    }

    #[test]
    fn norms_and_dot() {
        let x = [3.0f32, 4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-9);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-9);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 0.0]), 2.0);
    }

    #[test]
    fn mse_empty_is_zero() {
        assert_eq!(mse(&[], &[]), 0.0);
    }

    #[test]
    fn finite_detection() {
        assert!(all_finite(&[1.0, -2.0]));
        assert!(!all_finite(&[1.0, f32::NAN]));
        assert!(!all_finite(&[f32::INFINITY]));
    }
}
