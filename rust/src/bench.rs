//! Minimal benchmark harness (the offline build vendors no criterion).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary built on
//! this module: auto-calibrated iteration counts, warm-up, mean/std/min
//! reporting, and a `--save <id>` flag that appends JSON lines under
//! `results/bench/` so the perf pass can diff before/after.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

/// Runs and reports a group of benchmarks.
pub struct Runner {
    group: String,
    target_s: f64,
    results: Vec<Measurement>,
}

impl Runner {
    pub fn new(group: &str) -> Self {
        println!("== bench group: {group} ==");
        Runner { group: group.to_string(), target_s: 0.6, results: Vec::new() }
    }

    /// Override the per-benchmark sampling budget in seconds (clamped to
    /// [0.01, 10]). The 0.6 s default suits local perf runs; CI smoke
    /// passes use a small budget so every bench still executes — and
    /// persists a results line — without stalling the pipeline.
    pub fn set_target_s(&mut self, s: f64) {
        self.target_s = s.clamp(0.01, 10.0);
    }

    /// Benchmark a closure. The closure should return something observable
    /// (use `std::hint::black_box` inside for values you must not DCE).
    // Benchmarks are the other sanctioned wall-clock reader (clippy.toml
    // bans the raw call on solver paths).
    #[allow(clippy::disallowed_methods)]
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warm-up + calibration.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let warm = (0.05 / once).clamp(1.0, 20.0) as usize;
        for _ in 0..warm {
            f();
        }
        let iters = (self.target_s / once).clamp(5.0, 10_000.0) as usize;

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64() * 1e3);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / samples.len().max(1) as f64;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_ms: mean,
            std_ms: var.sqrt(),
            min_ms: min,
        };
        println!(
            "  {:<44} {:>10.4} ms/iter  (± {:>8.4}, min {:>8.4}, n={})",
            m.name, m.mean_ms, m.std_ms, m.min_ms, m.iters
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record a derived scalar (throughput in req/s, mean batch occupancy,
    /// a speedup ratio, …) as a result row so it persists in the group's
    /// jsonl next to the timing measurements. The value lands in the
    /// `mean_ms`/`min_ms` fields — they are the generic value slots of the
    /// row format — with `iters = 1` and zero spread marking it as a
    /// recorded quantity rather than a sampled timing.
    pub fn record(&mut self, name: &str, value: f64) {
        let m = Measurement {
            name: name.to_string(),
            iters: 1,
            mean_ms: value,
            std_ms: 0.0,
            min_ms: value,
        };
        println!("  {:<44} {:>10.4}  (recorded)", m.name, m.mean_ms);
        self.results.push(m);
    }

    /// Persist the group's results as JSON lines under `results/bench/`.
    ///
    /// The group id is interpolated into the output filename; ids with path
    /// separators or parent references are rejected (a stray `--save ../x`
    /// must not write outside the bench results dir).
    pub fn save(&self) {
        use crate::util::json::obj;
        if !safe_bench_id(&self.group) {
            eprintln!(
                "bench: refusing to save group {:?}: id must be a plain filename component",
                self.group
            );
            return;
        }
        let dir = crate::coordinator::results_dir().join("bench");
        if std::fs::create_dir_all(&dir).is_err() {
            return;
        }
        let mut lines = String::new();
        for m in &self.results {
            let j = obj(vec![
                ("group", self.group.as_str().into()),
                ("name", m.name.as_str().into()),
                ("mean_ms", m.mean_ms.into()),
                ("std_ms", m.std_ms.into()),
                ("min_ms", m.min_ms.into()),
                ("iters", m.iters.into()),
            ]);
            lines.push_str(&j.to_string());
            lines.push('\n');
        }
        let _ = std::fs::write(dir.join(format!("{}.jsonl", self.group)), lines);
    }
}

impl Drop for Runner {
    fn drop(&mut self) {
        self.save();
    }
}

/// True iff `id` is safe to use as a single filename component under
/// `results/bench/`: non-empty, no path separators, no parent references,
/// no leading dot, and nothing outside `[A-Za-z0-9._-]`.
pub fn safe_bench_id(id: &str) -> bool {
    !id.is_empty()
        && !id.starts_with('.')
        && !id.contains("..")
        && id.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_ids_reject_path_escapes() {
        assert!(safe_bench_id("serve_load"));
        assert!(safe_bench_id("fig7.train-step_2"));
        assert!(!safe_bench_id(""));
        assert!(!safe_bench_id("../evil"));
        assert!(!safe_bench_id("a/b"));
        assert!(!safe_bench_id("a\\b"));
        assert!(!safe_bench_id(".."));
        assert!(!safe_bench_id(".hidden"));
        assert!(!safe_bench_id("nul\0byte"));
    }

    #[test]
    fn record_appends_a_result_row() {
        let mut r = Runner::new("unit-record");
        r.record("throughput_rps", 1234.5);
        let m = r.results.last().unwrap();
        assert_eq!(m.name, "throughput_rps");
        assert_eq!(m.mean_ms, 1234.5);
        assert_eq!(m.min_ms, 1234.5);
        assert_eq!(m.iters, 1);
        assert_eq!(m.std_ms, 0.0);
    }

    #[test]
    fn bench_measures_something() {
        let mut r = Runner::new("unit");
        let m = r.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(m.mean_ms >= 0.0);
        assert!(m.min_ms <= m.mean_ms + 1e-9);
        assert!(m.iters >= 5);
    }
}
