//! End-to-end tests of the HTTP front door, driven by a hand-rolled
//! raw-socket HTTP/1.1 client (no client library — the test must not trust
//! the code under test to frame its own traffic).
//!
//! The load-bearing claims: served answers (forward, gradient,
//! dense-output) are bit-identical to direct engine calls; admission
//! backpressure surfaces as `429` with a `Retry-After` header; and
//! protocol-level garbage (malformed JSON, wrong wire version, oversized
//! bodies, broken request lines) bounces with `400` before any request
//! reaches admission or a worker.

use nodal::ckpt::CkptPolicy;
use nodal::grad::aca_backward;
use nodal::ode::analytic::VanDerPol;
use nodal::ode::dense::DenseOutput;
use nodal::ode::integrate;
use nodal::serve::{
    HttpConfig, HttpServer, ServeConfig, ServeError, SolveRequest, SolveResponse, SolveServer,
};
use nodal::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// One parsed HTTP response: status, lower-cased headers, body.
struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// Write one request. An explicit `content-length` is always sent (zero for
/// bodyless requests) so the server's framing is exercised uniformly.
fn send_request(s: &mut TcpStream, method: &str, path: &str, body: &str) {
    send_request_with(s, method, path, &[], body);
}

/// Like [`send_request`], with extra request headers.
fn send_request_with(
    s: &mut TcpStream,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &str,
) {
    let mut req = format!("{method} {path} HTTP/1.1\r\ncontent-length: {}\r\n", body.len());
    for (k, v) in headers {
        req.push_str(&format!("{k}: {v}\r\n"));
    }
    req.push_str("\r\n");
    req.push_str(body);
    s.write_all(req.as_bytes()).unwrap();
}

/// Read one response off the wire; `None` means the peer closed it.
fn read_response(r: &mut BufReader<TcpStream>) -> Option<Response> {
    let mut line = String::new();
    if r.read_line(&mut line).ok()? == 0 {
        return None;
    }
    let status: u16 = line.split_whitespace().nth(1)?.parse().ok()?;
    let mut headers = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).ok()?;
        let h = h.trim_end().to_string();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':')?;
        let (k, v) = (k.trim().to_ascii_lowercase(), v.trim().to_string());
        if k == "content-length" {
            len = v.parse().ok()?;
        }
        headers.push((k, v));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).ok()?;
    Some(Response { status, headers, body: String::from_utf8(body).ok()? })
}

/// Connect a raw client to the front door: (write half, buffered read half).
fn connect(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let s = TcpStream::connect(addr).unwrap();
    let r = BufReader::new(s.try_clone().unwrap());
    (s, r)
}

fn spawn_front_door(cfg: ServeConfig, http_cfg: HttpConfig) -> (Arc<SolveServer>, HttpServer) {
    let server =
        Arc::new(SolveServer::builder().register("vdp", VanDerPol::new(0.5)).config(cfg).start());
    let http = HttpServer::spawn_at(server.clone(), "127.0.0.1:0", http_cfg).unwrap();
    (server, http)
}

fn fast_flush_config() -> ServeConfig {
    ServeConfig {
        max_batch_size: 8,
        // Tiny deadline: singleton batches flush on the next batcher tick
        // instead of waiting for co-traffic (HTTP requests block their
        // connection until answered).
        max_queue_delay: Duration::from_micros(50),
        queue_capacity: 64,
        workers: 2,
        ckpt_budget_bytes: 0,
        mem_budget_bytes: 0,
        quota_quantum: 32,
        quota_max_deficit: 128,
    }
}

/// Forward, gradient, and dense-output requests over ONE keep-alive
/// connection: every payload class decodes from the wire bit-identical to
/// the direct engine call, and the liveness/metrics routes answer on the
/// same socket afterwards.
#[test]
fn http_round_trip_matches_direct_solves_on_one_connection() {
    let (server, mut http) = spawn_front_door(fast_flush_config(), HttpConfig::default());
    let vdp = VanDerPol::new(0.5);
    let (mut w, mut r) = connect(http.addr());

    // Forward request: bit-identical endpoint.
    let req = SolveRequest::fixed("vdp", 0.0, 1.5, vec![2.0, 0.0], 0.05).unwrap();
    send_request(&mut w, "POST", "/v1/solve", &req.to_json().to_string());
    let resp = read_response(&mut r).expect("forward response");
    assert_eq!(resp.status, 200, "{}", resp.body);
    let solved = SolveResponse::from_json(&Json::parse(&resp.body).unwrap()).unwrap();
    let mut opts = req.opts();
    opts.ckpt = CkptPolicy::from_budget(0);
    let traj = integrate(&vdp, 0.0, 1.5, &req.z0, req.tab, &opts).unwrap();
    assert_eq!(bits(solved.z_t1()), bits(traj.last().unwrap()), "forward drifted over HTTP");

    // Gradient request on the SAME connection (keep-alive): dL/dz0 and
    // dL/dθ cross the wire bit-exactly.
    let lam = vec![1.0f32, 0.0];
    let greq = SolveRequest::fixed("vdp", 0.0, 1.5, vec![2.0, 0.0], 0.05)
        .unwrap()
        .with_grad(lam.clone());
    send_request(&mut w, "POST", "/v1/solve", &greq.to_json().to_string());
    let resp = read_response(&mut r).expect("gradient response");
    assert_eq!(resp.status, 200, "{}", resp.body);
    let solved = SolveResponse::from_json(&Json::parse(&resp.body).unwrap()).unwrap();
    let direct = aca_backward(&vdp, greq.tab, &traj, &lam);
    let served = solved.grad().expect("gradient payload");
    assert_eq!(bits(&served.dl_dz0), bits(&direct.dl_dz0), "dL/dz0 drifted over HTTP");
    assert_eq!(bits(&served.dl_dtheta), bits(&direct.dl_dtheta), "dL/dθ drifted over HTTP");

    // Dense-output request, still the same connection: every observation
    // bit-equal to `DenseOutput::eval` on the direct solve.
    let grid = vec![0.1, 0.75, 1.4999];
    let oreq = SolveRequest::builder("vdp")
        .span(0.0, 1.5)
        .state(vec![2.0, 0.0])
        .fixed(0.05)
        .observe_at(grid.clone())
        .build()
        .unwrap();
    send_request(&mut w, "POST", "/v1/solve", &oreq.to_json().to_string());
    let resp = read_response(&mut r).expect("observed response");
    assert_eq!(resp.status, 200, "{}", resp.body);
    let solved = SolveResponse::from_json(&Json::parse(&resp.body).unwrap()).unwrap();
    let dense = DenseOutput::new(&vdp, &traj);
    let zs = solved.observations().expect("observed payload");
    assert_eq!(zs.len(), grid.len());
    for (&t, z) in grid.iter().zip(zs) {
        assert_eq!(bits(z), bits(&dense.eval(t)), "observation at t={t} drifted over HTTP");
    }

    // Unknown dynamics maps to 404 with the typed error body.
    let ghost = SolveRequest::fixed("ghost", 0.0, 1.0, vec![1.0], 0.1).unwrap();
    send_request(&mut w, "POST", "/v1/solve", &ghost.to_json().to_string());
    let resp = read_response(&mut r).expect("ghost response");
    assert_eq!(resp.status, 404);
    let err = ServeError::from_json(&Json::parse(&resp.body).unwrap()).unwrap();
    assert!(matches!(err, ServeError::UnknownDynamics(_)), "{err:?}");

    // Liveness and metrics still answer on the same socket.
    send_request(&mut w, "GET", "/healthz", "");
    let resp = read_response(&mut r).expect("healthz response");
    assert_eq!((resp.status, resp.body.as_str()), (200, "{\"ok\":true}"));
    send_request(&mut w, "GET", "/v1/metrics", "");
    let resp = read_response(&mut r).expect("metrics response");
    assert_eq!(resp.status, 200);
    let m = Json::parse(&resp.body).unwrap();
    assert_eq!(m.get("submitted").unwrap().as_usize().unwrap(), 3, "three admitted solves");

    http.shutdown();
    server.shutdown();
}

/// Admission backpressure crosses the HTTP boundary: with a one-slot
/// admission cap and a parked first request, the second solve answers
/// `429 Too Many Requests` carrying `Retry-After` and the typed
/// `overloaded` body — and the parked request still completes once drained.
#[test]
fn overloaded_maps_to_429_with_retry_after() {
    let cfg = ServeConfig {
        max_batch_size: 8,
        max_queue_delay: Duration::from_secs(3600), // park until drain
        queue_capacity: 1,
        workers: 1,
        ckpt_budget_bytes: 0,
        mem_budget_bytes: 0,
        quota_quantum: 32,
        quota_max_deficit: 128,
    };
    let (server, mut http) = spawn_front_door(cfg, HttpConfig::default());
    let addr = http.addr().to_string();
    let req = SolveRequest::fixed("vdp", 0.0, 1.0, vec![2.0, 0.0], 0.1).unwrap();

    std::thread::scope(|sc| {
        let parked = {
            let (addr, req) = (addr.clone(), req.clone());
            sc.spawn(move || {
                let (mut w, mut r) = connect(&addr);
                send_request(&mut w, "POST", "/v1/solve", &req.to_json().to_string());
                read_response(&mut r).expect("parked request must eventually answer")
            })
        };
        // Wait until the first request holds the only admission slot.
        for _ in 0..400 {
            if server.inflight() == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(server.inflight(), 1, "the parked request must be admitted");

        let (mut w, mut r) = connect(&addr);
        send_request(&mut w, "POST", "/v1/solve", &req.to_json().to_string());
        let resp = read_response(&mut r).expect("shed request answers immediately");
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"), "429 must carry Retry-After");
        let err = ServeError::from_json(&Json::parse(&resp.body).unwrap()).unwrap();
        assert_eq!(err, ServeError::Overloaded);

        // Release the parked request and check it was served, not dropped.
        server.drain();
        let resp = parked.join().unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let solved = SolveResponse::from_json(&Json::parse(&resp.body).unwrap()).unwrap();
        let vdp = VanDerPol::new(0.5);
        let mut opts = req.opts();
        opts.ckpt = CkptPolicy::from_budget(0);
        let traj = integrate(&vdp, 0.0, 1.0, &req.z0, req.tab, &opts).unwrap();
        assert_eq!(bits(solved.z_t1()), bits(traj.last().unwrap()));
    });
    http.shutdown();
    server.shutdown();
}

/// Protocol-level garbage is rejected with `400` BEFORE admission: after a
/// malformed-JSON body, a wrong wire version, an oversized body, and a
/// broken request line, the server has admitted zero requests and executed
/// zero batches.
#[test]
fn garbage_never_reaches_a_worker() {
    let http_cfg = HttpConfig { max_body_bytes: 1024, ..HttpConfig::default() };
    let (server, mut http) = spawn_front_door(fast_flush_config(), http_cfg);

    // Malformed JSON: 400, and the connection survives (framing is intact).
    let (mut w, mut r) = connect(http.addr());
    send_request(&mut w, "POST", "/v1/solve", "{not json");
    let resp = read_response(&mut r).expect("malformed-JSON response");
    assert_eq!(resp.status, 400);
    let err = ServeError::from_json(&Json::parse(&resp.body).unwrap()).unwrap();
    assert!(matches!(err, ServeError::BadRequest(_)), "{err:?}");
    send_request(&mut w, "GET", "/healthz", "");
    assert_eq!(read_response(&mut r).expect("conn survives").status, 200);

    // Wrong wire version: a typed 400, same connection.
    let good = SolveRequest::fixed("vdp", 0.0, 1.0, vec![2.0, 0.0], 0.1).unwrap();
    let mut versioned = good.to_json();
    if let Json::Obj(m) = &mut versioned {
        m.insert("v".into(), 99.0.into());
    }
    send_request(&mut w, "POST", "/v1/solve", &versioned.to_string());
    let resp = read_response(&mut r).expect("wrong-version response");
    assert_eq!(resp.status, 400);
    assert!(resp.body.contains("unsupported wire version 99"), "{}", resp.body);

    // Oversized body: refused from the content-length header alone — the
    // 400 arrives without the body ever being sent, then the connection
    // closes (the unread bytes make it unframeable).
    let (mut w, mut r) = connect(http.addr());
    w.write_all(b"POST /v1/solve HTTP/1.1\r\ncontent-length: 2048\r\n\r\n").unwrap();
    let resp = read_response(&mut r).expect("oversized response");
    assert_eq!(resp.status, 400);
    assert!(resp.body.contains("max_body_bytes"), "{}", resp.body);
    assert!(read_response(&mut r).is_none(), "oversized poisons the connection");

    // Broken request line: 400, connection closed.
    let (mut w, mut r) = connect(http.addr());
    w.write_all(b"BLARG\r\n\r\n").unwrap();
    let resp = read_response(&mut r).expect("broken-line response");
    assert_eq!(resp.status, 400);
    assert!(read_response(&mut r).is_none(), "broken framing poisons the connection");

    // Unknown routes and methods get their own statuses, still pre-submit.
    let (mut w, mut r) = connect(http.addr());
    send_request(&mut w, "GET", "/nope", "");
    assert_eq!(read_response(&mut r).expect("404 route").status, 404);
    send_request(&mut w, "DELETE", "/v1/solve", "");
    assert_eq!(read_response(&mut r).expect("405 method").status, 405);

    // The acceptance claim: none of the above touched the solve pipeline.
    let m = server.metrics();
    assert_eq!(m.submitted, 0, "garbage must never be admitted");
    assert_eq!(m.batches, 0, "garbage must never dispatch a batch");
    http.shutdown();
    server.shutdown();
}

/// An `x-nodal-trace` request header turns on tracing for that one request:
/// the id echoes back on the response, `GET /v1/trace/<id>` then serves the
/// full span tree (front-door spans plus queue/batch/solve phases), the
/// JSONL export lands in the configured directory, unknown and malformed
/// ids answer 404, and the Prometheus metrics view answers next to JSON —
/// all on one keep-alive connection.
#[test]
fn trace_header_round_trips_and_trace_route_serves_spans() {
    use nodal::obs::{self, TraceKnobs};

    let dir = std::env::temp_dir().join(format!("nodal-trace-http-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let http_cfg = HttpConfig {
        trace: TraceKnobs { sample_n: 0, dir: dir.clone() },
        ..HttpConfig::default()
    };
    let (server, mut http) = spawn_front_door(fast_flush_config(), http_cfg);
    let (mut w, mut r) = connect(http.addr());

    let id = "00000000000000ab";
    let req = SolveRequest::fixed("vdp", 0.0, 1.0, vec![2.0, 0.0], 0.1).unwrap();
    let hdrs = [("x-nodal-trace", id)];
    send_request_with(&mut w, "POST", "/v1/solve", &hdrs, &req.to_json().to_string());
    let resp = read_response(&mut r).expect("traced solve response");
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(resp.header("x-nodal-trace"), Some(id), "trace id echoes on the response");

    // The trace route serves the stitched span tree for that id. The
    // response bytes were written only after publish + export, so this
    // read-after-answer is not racy.
    send_request(&mut w, "GET", &format!("/v1/trace/{id}"), "");
    let resp = read_response(&mut r).expect("trace route response");
    assert_eq!(resp.status, 200, "{}", resp.body);
    let doc = Json::parse(&resp.body).unwrap();
    let spans = obs::spans_from_json(doc.get("spans").unwrap());
    let names: Vec<&str> = spans.iter().map(|s| s.name).collect();
    for want in ["http_request", "admission", "queue_wait", "batch_form", "solve", "forward"] {
        assert!(names.contains(&want), "missing {want} span in {names:?}");
    }
    let root = spans.iter().find(|s| s.name == "http_request").unwrap();
    assert_eq!(root.parent, 0, "http_request is the trace root");
    assert_eq!(root.get_attr("status"), Some(200), "root records the HTTP status");
    let solve = spans.iter().find(|s| s.name == "solve").unwrap();
    let fwd = spans.iter().find(|s| s.name == "forward").unwrap();
    assert_eq!(fwd.parent, solve.span, "forward nests under solve");

    // Deterministic JSONL export landed under the configured directory.
    assert!(dir.join(format!("{id}.jsonl")).is_file(), "trace export written");

    // Unknown and malformed ids are 404s, same connection.
    send_request(&mut w, "GET", "/v1/trace/00000000000000ff", "");
    assert_eq!(read_response(&mut r).expect("unknown id").status, 404);
    send_request(&mut w, "GET", "/v1/trace/zzz", "");
    assert_eq!(read_response(&mut r).expect("malformed id").status, 404);

    // Prometheus exposition rides the same metrics route.
    send_request(&mut w, "GET", "/v1/metrics?format=prometheus", "");
    let resp = read_response(&mut r).expect("prometheus response");
    assert_eq!(resp.status, 200);
    assert!(
        resp.header("content-type").unwrap_or("").starts_with("text/plain"),
        "prometheus view is text exposition"
    );
    assert!(resp.body.contains("nodal_requests_completed_total 1"), "{}", resp.body);
    assert!(resp.body.contains("nodal_http_connections_accepted_total 1"), "{}", resp.body);

    http.shutdown();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
