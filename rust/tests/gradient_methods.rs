//! Cross-method integration tests on analytic dynamics (no artifacts):
//! the three gradient methods agree where they must, diverge where the
//! paper says they do, and their cost meters respect the Table 1 ordering.

use nodal::grad::{self, aca_backward, Method};
use nodal::ode::analytic::{ConvFlow, Linear, ThreeBody, VanDerPol};
use nodal::ode::{integrate, tableau, IntegrateOpts, OdeFunc};

fn toy_setup(
    k: f32,
    t_end: f64,
    tol: f64,
) -> (Linear, nodal::ode::Trajectory, Vec<f32>, IntegrateOpts) {
    let f = Linear::new(k, 1);
    let opts = IntegrateOpts {
        record_trials: true,
        ..IntegrateOpts::with_tol(tol, tol * 1e-2)
    };
    let traj = integrate(&f, 0.0, t_end, &[1.0], tableau::dopri5(), &opts).unwrap();
    let zt = traj.last().unwrap()[0];
    let lam = vec![2.0 * zt];
    (f, traj, lam, opts)
}

#[test]
fn all_methods_approximate_analytic_gradient() {
    let (f, traj, lam, opts) = toy_setup(-0.5, 5.0, 1e-6);
    let exact = f.exact_dl_dz0(1.0, 5.0);
    for method in Method::all() {
        let g = grad::backward(&f, tableau::dopri5(), &traj, &lam, method, &opts).unwrap();
        let rel = ((g.dl_dz0[0] as f64 - exact) / exact).abs();
        // naive's h-chain terms allow a looser band (paper Sec 3.3)
        let band = if method == Method::Naive { 0.05 } else { 1e-3 };
        assert!(rel < band, "{}: rel err {rel}", method.name());
    }
}

#[test]
fn aca_most_accurate_on_parameter_gradient() {
    let (f, traj, lam, opts) = toy_setup(0.5, 6.0, 1e-5);
    let exact = f.exact_dl_dk(1.0, 6.0);
    let mut errs = std::collections::HashMap::new();
    for method in Method::all() {
        let g = grad::backward(&f, tableau::dopri5(), &traj, &lam, method, &opts).unwrap();
        errs.insert(method.name(), ((g.dl_dtheta[0] as f64 - exact) / exact).abs());
    }
    // The paper's ordering: ACA best; naive's vanishing-gradient pathology
    // makes it worst by far on dk.
    assert!(errs["aca"] <= errs["adjoint"] * 2.0, "{errs:?}");
    assert!(errs["naive"] > 10.0 * errs["aca"], "{errs:?}");
}

#[test]
fn table1_cost_ordering() {
    // On a workload with rejections: ACA fewest backward NFE, adjoint
    // smallest memory, naive deepest graph. (mu kept moderate: the adjoint's
    // reverse-time solve of a strongly anti-damped van der Pol underflows —
    // that divergence is itself the paper's point, tested separately below.)
    let f = VanDerPol::new(1.5);
    let tab = tableau::dopri5();
    let opts = IntegrateOpts {
        record_trials: true,
        h0: Some(1.0),
        ..IntegrateOpts::with_tol(1e-5, 1e-7)
    };
    let traj = integrate(&f, 0.0, 5.0, &[2.0, 0.0], tab, &opts).unwrap();
    assert!(traj.n_rejected > 0);
    let lam = [1.0f32, -1.0];
    let mut meters = std::collections::HashMap::new();
    for method in Method::all() {
        let g = grad::backward(&f, tab, &traj, &lam, method, &opts).unwrap();
        meters.insert(method.name(), g.meter);
    }
    let aca = &meters["aca"];
    let naive = &meters["naive"];
    let adj = &meters["adjoint"];
    assert!(aca.nfe_backward <= naive.nfe_backward, "compute: ACA <= naive");
    assert!(adj.checkpoint_bytes < aca.checkpoint_bytes, "memory: adjoint < ACA");
    assert!(aca.checkpoint_bytes < naive.checkpoint_bytes, "memory: ACA < naive");
    assert!(aca.graph_depth < naive.graph_depth, "depth: ACA < naive");
    assert!(adj.n_reverse_steps > 0, "adjoint reverse solve ran");
}

#[test]
fn aca_gradient_invariant_to_trial_recording() {
    // ACA must ignore rejected-trial records entirely.
    let f = VanDerPol::new(2.0);
    let tab = tableau::rk23();
    let mk = |record| {
        let opts = IntegrateOpts {
            record_trials: record,
            h0: Some(0.7),
            ..IntegrateOpts::with_tol(1e-5, 1e-7)
        };
        let traj = integrate(&f, 0.0, 3.0, &[2.0, 0.0], tab, &opts).unwrap();
        aca_backward(&f, tab, &traj, &[1.0, 0.5]).dl_dz0
    };
    assert_eq!(mk(true), mk(false));
}

#[test]
fn linear_flow_gradient_is_transpose_of_flow() {
    // For the linear conv flow, dL/dz0 = (e^{K T})^T λ: check via the
    // adjoint identity <λ, Φ v> == <dL/dz0-with-λ, v>.
    let f = ConvFlow::random(6, 6, 5, 0.3);
    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
    let dim = f.dim();
    let mut rng = nodal::util::Pcg64::seed(2);
    let z0: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
    let lam: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();

    let traj_v = integrate(&f, 0.0, 1.0, &v, tab, &opts).unwrap();
    let lhs = nodal::tensor::dot(&lam, traj_v.last().unwrap());

    let traj = integrate(&f, 0.0, 1.0, &z0, tab, &opts).unwrap();
    let g = aca_backward(&f, tab, &traj, &lam);
    let rhs = nodal::tensor::dot(&g.dl_dz0, &v);
    assert!(
        (lhs - rhs).abs() < 2e-3 * lhs.abs().max(1.0),
        "flow-transpose identity: {lhs} vs {rhs}"
    );
}

#[test]
fn three_body_mass_gradient_descends() {
    // One gradient step on the masses must reduce the one-segment loss.
    let ds = nodal::data::ThreeBodyDataset::generate(2, 50);
    let f = ThreeBody::new([0.7, 0.7, 0.7]);
    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(1e-6, 1e-6);

    let loss_of = |f: &ThreeBody| -> f64 {
        let traj = integrate(f, ds.times[0], ds.times[10], &ds.states[0], tab, &opts).unwrap();
        let target = ds.positions(10);
        (0..9)
            .map(|j| ((traj.last().unwrap()[j] - target[j]) as f64).powi(2))
            .sum::<f64>()
            / 9.0
    };

    let traj = integrate(&f, ds.times[0], ds.times[10], &ds.states[0], tab, &opts).unwrap();
    let target = ds.positions(10);
    let mut lam = vec![0.0f32; 18];
    for j in 0..9 {
        lam[j] = 2.0 * (traj.last().unwrap()[j] - target[j]) / 9.0;
    }
    let g = aca_backward(&f, tab, &traj, &lam);
    let l0 = loss_of(&f);
    let step = 0.05f32 / nodal::tensor::norm2(&g.dl_dtheta).max(1e-9) as f32;
    let m2: Vec<f32> = f
        .params()
        .iter()
        .zip(&g.dl_dtheta)
        .map(|(m, d)| (m - step * d).max(1e-3))
        .collect();
    let l1 = loss_of(&ThreeBody::new([m2[0], m2[1], m2[2]]));
    assert!(l1 < l0, "mass gradient step must descend: {l0} -> {l1}");
}

#[test]
fn adjoint_vs_aca_gap_shrinks_with_tolerance() {
    // Theorem 3.2: the adjoint's extra error is O(h^p); tightening tol must
    // shrink the ACA-vs-adjoint disagreement.
    let f = VanDerPol::new(1.0);
    let tab = tableau::dopri5();
    let mut gaps = Vec::new();
    for tol in [1e-3, 1e-7] {
        let opts = IntegrateOpts::with_tol(tol, tol * 1e-2);
        let traj = integrate(&f, 0.0, 6.0, &[2.0, 0.0], tab, &opts).unwrap();
        let lam = [1.0f32, 0.0];
        let a = aca_backward(&f, tab, &traj, &lam);
        let j = grad::adjoint_backward(
            &f,
            tab,
            &traj,
            &lam,
            &grad::AdjointOpts::from_integrate(&opts),
        )
        .unwrap();
        gaps.push(nodal::tensor::max_abs_diff(&a.dl_dz0, &j.dl_dz0) as f64);
    }
    assert!(
        gaps[1] < gaps[0],
        "tighter tolerance must shrink the method gap: {gaps:?}"
    );
}

#[test]
fn backward_over_reverse_trajectory() {
    // Gradient methods must also work on backward-time trajectories
    // (t1 < t0), as used inside the adjoint and Fig 4/5 experiments.
    let f = Linear::new(-0.4, 2);
    let tab = tableau::rk23();
    let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
    let traj = integrate(&f, 2.0, 0.0, &[1.0, -1.0], tab, &opts).unwrap();
    let g = aca_backward(&f, tab, &traj, &[1.0, 1.0]);
    // z(0) = z(2) e^{0.8}: dL/dz(2) = e^{0.8} per component.
    let want = (0.8f64).exp();
    for v in &g.dl_dz0 {
        assert!((*v as f64 - want).abs() < 1e-3, "{v} vs {want}");
    }
}

#[test]
fn adjoint_reverse_solve_can_diverge_where_aca_cannot() {
    // mu = 3 van der Pol: reverse-time integration is violently anti-damped.
    // The continuous adjoint must re-solve the state backward and underflows;
    // ACA replays checkpoints and is immune (paper Sec 3.2).
    let f = VanDerPol::new(3.0);
    let tab = tableau::dopri5();
    let opts = IntegrateOpts {
        record_trials: true,
        h0: Some(1.0),
        ..IntegrateOpts::with_tol(1e-5, 1e-7)
    };
    let traj = integrate(&f, 0.0, 5.0, &[2.0, 0.0], tab, &opts).unwrap();
    let lam = [1.0f32, -1.0];
    // ACA: fine.
    let g = aca_backward(&f, tab, &traj, &lam);
    assert!(g.dl_dz0.iter().all(|v| v.is_finite()));
    // Adjoint: diverges (error) or produces a wildly different gradient.
    match grad::adjoint_backward(&f, tab, &traj, &lam, &grad::AdjointOpts::from_integrate(&opts)) {
        Err(_) => {} // step-size underflow — the expected failure
        Ok(j) => {
            let d = nodal::tensor::max_abs_diff(&g.dl_dz0, &j.dl_dz0) as f64;
            let scale = nodal::tensor::norm2(&g.dl_dz0);
            assert!(d > 0.1 * scale, "expected large adjoint error, got {d} vs {scale}");
        }
    }
}
