//! End-to-end tests of the solve server: equivalence with direct engine
//! calls, admission control/backpressure, and shutdown/drain semantics.
//!
//! No `sleep`-based assertions anywhere: timing-sensitive behavior runs
//! under an injected [`ManualClock`] with explicit `drain()`, and blocking
//! behavior is forced with a condition-variable-gated dynamics instead of
//! timing races.

use nodal::ckpt::CkptPolicy;
use nodal::grad::aca_backward;
use nodal::ode::analytic::{ConvFlow, Linear, VanDerPol};
use nodal::ode::dense::DenseOutput;
use nodal::ode::{integrate, integrate_batch, tableau, IntegrateOpts, OdeFunc};
use nodal::obs::{self, TraceCtx};
use nodal::serve::{
    Clock, FlushReason, Lane, ManualClock, ServeConfig, ServeError, SolveRequest, SolveServer,
};
use nodal::util::Pcg64;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A dynamics whose evaluations block until the test opens the gate —
/// deterministic worker stalling without sleeps.
struct Gated {
    inner: Linear,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Gated {
    fn new() -> (Self, Arc<(Mutex<bool>, Condvar)>) {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        (Gated { inner: Linear::new(-0.5, 2), gate: gate.clone() }, gate)
    }
}

fn open_gate(gate: &(Mutex<bool>, Condvar)) {
    *gate.0.lock().unwrap() = true;
    gate.1.notify_all();
}

/// Opens the gate on drop, so an assertion failure while workers are gated
/// still lets the server's Drop → shutdown() join its threads instead of
/// turning the test failure into a permanent hang.
struct GateOpener(Arc<(Mutex<bool>, Condvar)>);

impl Drop for GateOpener {
    fn drop(&mut self) {
        open_gate(&self.0);
    }
}

impl OdeFunc for Gated {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        let open = self.gate.0.lock().unwrap();
        let _open = self.gate.1.wait_while(open, |o| !*o).unwrap();
        self.inner.eval(t, z, dz);
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        self.inner.vjp(t, z, w, wjz, wjp);
    }
}

fn test_config(max_batch: usize, cap: usize, workers: usize) -> ServeConfig {
    ServeConfig {
        max_batch_size: max_batch,
        // Far beyond anything a test waits out — deadline flushes can only
        // come from the policy logic, never from wall time passing.
        max_queue_delay: Duration::from_secs(3600),
        queue_capacity: cap,
        workers,
        ckpt_budget_bytes: 0,
        mem_budget_bytes: 0,
        quota_quantum: 32,
        quota_max_deficit: 128,
    }
}

/// Served results are bit-identical to direct `integrate` /
/// `integrate_batch` calls for fixed-step requests, and within adaptive
/// tolerance (in fact the engine guarantees bit-equality there too) for
/// adaptive ones — co-batching must never change a request's answer.
#[test]
fn served_results_match_direct_solves() {
    let vdp = VanDerPol::new(0.5);
    let conv = ConvFlow::random(4, 4, 7, 0.4);
    let server = SolveServer::builder()
        .register("vdp", vdp.clone())
        .register("conv", conv.clone())
        .config(test_config(8, 256, 2))
        .start();

    let mut rng = Pcg64::seed(42);
    let fixed_z0: Vec<Vec<f32>> =
        (0..6).map(|_| (0..2).map(|_| rng.range(-1.5, 1.5) as f32).collect()).collect();
    let adaptive_z0: Vec<Vec<f32>> =
        (0..5).map(|_| (0..16).map(|_| rng.range(-1.0, 1.0) as f32).collect()).collect();

    // Mixed traffic: fixed-step van der Pol + adaptive conv-flow, all
    // submitted concurrently so the former is free to co-batch them.
    let fixed_handles: Vec<_> = fixed_z0
        .iter()
        .map(|z0| {
            server
                .submit(SolveRequest::fixed("vdp", 0.0, 1.5, z0.clone(), 0.05).unwrap())
                .unwrap()
        })
        .collect();
    let adaptive_handles: Vec<_> = adaptive_z0
        .iter()
        .map(|z0| {
            server
                .submit(
                    SolveRequest::adaptive("conv", 0.0, 2.0, z0.clone(), 1e-6, 1e-8).unwrap(),
                )
                .unwrap()
        })
        .collect();
    server.drain();

    // Fixed-step: bit-identical to the scalar path AND the batch engine.
    let fixed_opts = IntegrateOpts::fixed(0.05);
    let flat: Vec<f32> = fixed_z0.iter().flatten().copied().collect();
    let bt = integrate_batch(&vdp, 0.0, 1.5, &flat, tableau::rk4(), &fixed_opts).unwrap();
    for (i, (h, z0)) in fixed_handles.into_iter().zip(&fixed_z0).enumerate() {
        let resp = h.wait().unwrap();
        let direct = integrate(&vdp, 0.0, 1.5, z0, tableau::rk4(), &fixed_opts).unwrap();
        assert_eq!(resp.z_t1(), direct.last().unwrap(), "sample {i}: served != scalar");
        assert_eq!(resp.z_t1(), bt.last(i), "sample {i}: served != integrate_batch");
        assert_eq!(resp.stats.nfe, direct.nfe, "sample {i}: nfe accounting");
        assert_eq!(resp.stats.steps, direct.len());
        assert!(resp.stats.batch_size >= 1);
    }

    // Adaptive: within tolerance of the scalar path (per-sample step
    // control makes this bit-exact in practice; assert the guarantee).
    let ad_opts = IntegrateOpts::with_tol(1e-6, 1e-8);
    for (i, (h, z0)) in adaptive_handles.into_iter().zip(&adaptive_z0).enumerate() {
        let resp = h.wait().unwrap();
        let direct = integrate(&conv, 0.0, 2.0, z0, tableau::dopri5(), &ad_opts).unwrap();
        for (a, b) in resp.z_t1().iter().zip(direct.last().unwrap()) {
            assert!(
                (a - b).abs() as f64 <= 1e-6 * (b.abs() as f64).max(1.0),
                "adaptive sample {i}: {a} vs {b}"
            );
        }
        assert_eq!(resp.stats.nfe, direct.nfe, "adaptive sample {i}: nfe");
    }

    let m = server.metrics();
    assert_eq!(m.completed, 11);
    assert_eq!(m.rejected, 0);
    assert!(m.batches >= 2, "two incompatible groups can never share a batch");
}

/// Gradient requests return the exact batched-ACA gradients.
#[test]
fn served_gradients_match_aca_backward() {
    let vdp = VanDerPol::new(0.4);
    let server = SolveServer::builder()
        .register("vdp", vdp.clone())
        .config(test_config(8, 64, 2))
        .start();
    let mut rng = Pcg64::seed(7);
    let cases: Vec<(Vec<f32>, Vec<f32>)> = (0..5)
        .map(|_| {
            let z0 = vec![rng.range(-1.5, 1.5) as f32, rng.range(-1.5, 1.5) as f32];
            let lam = vec![rng.normal_f32(), rng.normal_f32()];
            (z0, lam)
        })
        .collect();
    let handles: Vec<_> = cases
        .iter()
        .map(|(z0, lam)| {
            server
                .submit(
                    SolveRequest::fixed("vdp", 0.0, 1.0, z0.clone(), 0.02)
                        .unwrap()
                        .with_grad(lam.clone()),
                )
                .unwrap()
        })
        .collect();
    server.drain();
    let opts = IntegrateOpts::fixed(0.02);
    for (i, (h, (z0, lam))) in handles.into_iter().zip(&cases).enumerate() {
        let resp = h.wait().unwrap();
        let traj = integrate(&vdp, 0.0, 1.0, z0, tableau::rk4(), &opts).unwrap();
        let direct = aca_backward(&vdp, tableau::rk4(), &traj, lam);
        let served = resp.grad().expect("gradient requested");
        assert_eq!(served.dl_dz0, direct.dl_dz0, "sample {i}: dL/dz0");
        assert_eq!(served.meter.nfe_backward, direct.meter.nfe_backward, "sample {i}");
    }
}

/// Admission control: with workers deterministically stalled, the
/// `queue_capacity`-th + 1 submission bounces with `Overloaded`; once the
/// gate opens and the backlog drains, the server admits again.
#[test]
fn overloaded_on_full_queue_then_recovers() {
    let (gated, gate) = Gated::new();
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("gated", gated)
        .config(test_config(1, 4, 1))
        .clock(clock)
        .start();
    // Declared AFTER `server` so it drops FIRST during a panic unwind —
    // the gate must open before SolveServer::drop joins the gated worker.
    let opener = GateOpener(gate);

    let req = || SolveRequest::fixed("gated", 0.0, 1.0, vec![1.0, 0.0], 0.25).unwrap();
    let handles: Vec<_> = (0..4).map(|_| server.submit(req()).unwrap()).collect();
    let err = server.submit(req()).unwrap_err();
    assert_eq!(err, ServeError::Overloaded, "capacity 4 must bounce the 5th request");
    assert_eq!(server.metrics().rejected, 1);

    drop(opener); // open the gate
    server.drain();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait().unwrap();
        assert_eq!(resp.stats.batch_size, 1, "request {i} served with max_batch_size=1");
    }
    let h = server.submit(req()).unwrap();
    assert!(h.wait().is_ok(), "admission must recover after the backlog drains");
}

/// `drain()` flushes partial groups below both flush thresholds — the
/// virtual clock never reaches the deadline and the group never fills, yet
/// every request completes.
#[test]
fn drain_flushes_partial_batches_without_deadline() {
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("linear", Linear::new(-0.8, 4))
        .config(test_config(64, 256, 2))
        .clock(clock.clone())
        .start();
    let handles: Vec<_> = (0..3)
        .map(|i| {
            server
                .submit(
                    SolveRequest::fixed("linear", 0.0, 1.0, vec![i as f32, 1.0, -1.0, 0.5], 0.1)
                        .unwrap(),
                )
                .unwrap()
        })
        .collect();
    assert_eq!(clock.now(), Duration::ZERO, "virtual time never advanced");
    server.drain();
    for h in handles {
        let resp = h.wait().unwrap();
        assert_eq!(resp.stats.batch_size, 3, "one coalesced batch of all three");
    }
    let m = server.metrics();
    assert_eq!(m.completed, 3);
    assert_eq!(m.batch_sizes[3], 1);
}

/// Shutdown must answer every admitted request (drain, not drop) and then
/// reject new work.
#[test]
fn shutdown_drains_in_flight_requests() {
    let server = SolveServer::builder()
        .register("linear", Linear::new(-0.5, 2))
        .config(test_config(4, 256, 2))
        .start();
    let handles: Vec<_> = (0..32)
        .map(|i| {
            server
                .submit(
                    SolveRequest::fixed("linear", 0.0, 1.0, vec![i as f32, -1.0], 0.05).unwrap(),
                )
                .unwrap()
        })
        .collect();
    server.shutdown();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait();
        assert!(resp.is_ok(), "request {i} dropped during shutdown: {resp:?}");
    }
    assert_eq!(
        server
            .submit(SolveRequest::fixed("linear", 0.0, 1.0, vec![0.0, 0.0], 0.05).unwrap())
            .unwrap_err(),
        ServeError::ShuttingDown
    );
    assert_eq!(server.metrics().completed, 32);
}

/// Wraps Van der Pol and counts which engine entry points ran: the batched
/// stage sweeps (`eval_batch`/`vjp_batch` — the `integrate_batch_spans` /
/// `aca_backward_batch` path) versus the scalar entry points (`eval`/`vjp`
/// — what the per-sample fallback and direct `integrate` calls use). Zero
/// scalar calls proves the whole batch was served by the batched engine.
struct EntryCounting {
    inner: VanDerPol,
    scalar_evals: Arc<std::sync::atomic::AtomicUsize>,
    batch_evals: Arc<std::sync::atomic::AtomicUsize>,
    scalar_vjps: Arc<std::sync::atomic::AtomicUsize>,
    batch_vjps: Arc<std::sync::atomic::AtomicUsize>,
}

impl EntryCounting {
    #[allow(clippy::type_complexity)]
    fn new(
        inner: VanDerPol,
    ) -> (
        Self,
        Arc<std::sync::atomic::AtomicUsize>,
        Arc<std::sync::atomic::AtomicUsize>,
        Arc<std::sync::atomic::AtomicUsize>,
        Arc<std::sync::atomic::AtomicUsize>,
    ) {
        let mk = || Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let (se, be, sv, bv) = (mk(), mk(), mk(), mk());
        let f = EntryCounting {
            inner,
            scalar_evals: se.clone(),
            batch_evals: be.clone(),
            scalar_vjps: sv.clone(),
            batch_vjps: bv.clone(),
        };
        (f, se, be, sv, bv)
    }
}

impl OdeFunc for EntryCounting {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        self.scalar_evals.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.eval(t, z, dz)
    }
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        self.batch_evals.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.eval_batch(ts, zs, dzs)
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        self.scalar_vjps.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.vjp(t, z, w, wjz, wjp)
    }
    fn vjp_batch(&self, ts: &[f64], zs: &[f32], ws: &[f32], wjzs: &mut [f32], wjps: &mut [f32]) {
        self.batch_vjps.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        self.inner.vjp_batch(ts, zs, ws, wjzs, wjps)
    }
}

/// The tentpole guarantee, forward half: four requests with identical
/// dynamics/solver/tolerance but four **distinct `t1` values** form ONE
/// batch and execute as ONE `integrate_batch_spans` call — asserted by
/// dispatch accounting (exactly one executed batch of size 4, stage-sweep
/// dispatch count matching the batched engine's schedule, zero scalar
/// entry-point calls) — and every response is bit-identical to its direct
/// single-request solve, NFE accounting included.
#[test]
fn mixed_span_forward_batch_runs_once_and_matches_direct() {
    let vdp = VanDerPol::new(0.5);
    let (f, scalar_evals, batch_evals, _, _) = EntryCounting::new(vdp.clone());
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("vdp", f)
        .config(test_config(16, 64, 1))
        .clock(clock)
        .start();

    // Distinct spans, distinct states; fixed step keeps every dispatch on
    // the batched sweeps (adaptive auto-h0 probes f through scalar `eval`).
    // The step and the endpoints are dyadic, so per-sample step counts are
    // exact (16/24/32/40) and the dispatch accounting below is not hostage
    // to float accumulation.
    let t1s = [1.0f64, 1.5, 2.0, 2.5];
    let z0s: Vec<Vec<f32>> = (0..4).map(|i| vec![0.4 * i as f32 - 0.5, 0.3]).collect();
    let handles: Vec<_> = t1s
        .iter()
        .zip(&z0s)
        .map(|(&t1, z0)| {
            server
                .submit(SolveRequest::fixed("vdp", 0.0, t1, z0.clone(), 0.0625).unwrap())
                .unwrap()
        })
        .collect();
    server.drain();

    let m = server.metrics();
    assert_eq!(m.batches, 1, "four spans must execute as ONE batch");
    assert_eq!(m.batch_sizes[4], 1);
    assert_eq!(
        scalar_evals.load(std::sync::atomic::Ordering::SeqCst),
        0,
        "no scalar fallback: the batch ran through integrate_batch_spans alone"
    );
    // Dispatch accounting: rk4 (4 stages, no FSAL) costs 4 eval_batch
    // sweeps per round; rounds = the longest sample's step count
    // (2.5 / 0.0625 = 40) since shorter samples retire from the active set.
    assert_eq!(batch_evals.load(std::sync::atomic::Ordering::SeqCst), 4 * 40);

    let opts = IntegrateOpts::fixed(0.0625);
    for ((h, &t1), z0) in handles.into_iter().zip(&t1s).zip(&z0s) {
        let resp = h.wait().unwrap();
        let direct = integrate(&vdp, 0.0, t1, z0, tableau::rk4(), &opts).unwrap();
        assert_eq!(resp.z_t1(), direct.last().unwrap(), "t1={t1}: served != direct solve");
        assert_eq!(resp.stats.nfe, direct.nfe, "t1={t1}: NFE accounting");
        assert_eq!(resp.stats.steps, direct.len(), "t1={t1}: steps");
        assert_eq!(resp.stats.batch_size, 4, "t1={t1}: co-batched with all four");
    }
}

/// The tentpole guarantee, gradient half: three gradient requests with
/// distinct `t1` values run as ONE forward `integrate_batch_spans` + ONE
/// shared-stage `aca_backward_batch` pass (zero scalar `eval`/`vjp` calls),
/// with `dL/dz0` and every backward meter bit-identical to the direct
/// per-request solve-and-backward.
#[test]
fn mixed_span_gradient_batch_runs_once_and_matches_direct() {
    let vdp = VanDerPol::new(0.5);
    let (f, scalar_evals, batch_evals, scalar_vjps, batch_vjps) = EntryCounting::new(vdp.clone());
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("vdp", f)
        .config(test_config(16, 64, 1))
        .clock(clock)
        .start();

    // Dyadic step and endpoints: exact per-sample step counts 12/20/24.
    let t1s = [0.75f64, 1.25, 1.5];
    let cases: Vec<(Vec<f32>, Vec<f32>)> = (0..3)
        .map(|i| (vec![0.5 * i as f32 - 0.4, 0.6], vec![1.0, -0.5 - 0.25 * i as f32]))
        .collect();
    let handles: Vec<_> = t1s
        .iter()
        .zip(&cases)
        .map(|(&t1, (z0, lam))| {
            server
                .submit(
                    SolveRequest::fixed("vdp", 0.0, t1, z0.clone(), 0.0625)
                        .unwrap()
                        .with_grad(lam.clone()),
                )
                .unwrap()
        })
        .collect();
    server.drain();

    let m = server.metrics();
    assert_eq!(m.batches, 1, "three spans must execute as ONE gradient batch");
    assert_eq!(m.batch_sizes[3], 1);
    assert_eq!(scalar_evals.load(std::sync::atomic::Ordering::SeqCst), 0, "no scalar eval");
    assert_eq!(scalar_vjps.load(std::sync::atomic::Ordering::SeqCst), 0, "no scalar vjp");
    // Dispatch accounting. Forward: 4 rk4 sweeps × 24 rounds (1.5 / 0.0625,
    // the deepest sample). Backward: the shared-stage sweep recomputes 4
    // stages per reverse round (eval_batch) and runs 4 live pullback sweeps
    // (vjp_batch; all stages live — rk4 has no zero b_j and the cotangents
    // are nonzero), again over 24 rounds keyed to the deepest sample.
    assert_eq!(batch_evals.load(std::sync::atomic::Ordering::SeqCst), 4 * 24 + 4 * 24);
    assert_eq!(batch_vjps.load(std::sync::atomic::Ordering::SeqCst), 4 * 24);

    let opts = IntegrateOpts::fixed(0.0625);
    for ((h, &t1), (z0, lam)) in handles.into_iter().zip(&t1s).zip(&cases) {
        let resp = h.wait().unwrap();
        let traj = integrate(&vdp, 0.0, t1, z0, tableau::rk4(), &opts).unwrap();
        let direct = aca_backward(&vdp, tableau::rk4(), &traj, lam);
        assert_eq!(resp.z_t1(), traj.last().unwrap(), "t1={t1}: forward");
        let served = resp.grad().expect("gradient requested");
        assert_eq!(served.dl_dz0, direct.dl_dz0, "t1={t1}: dL/dz0");
        assert_eq!(served.dl_dtheta, direct.dl_dtheta, "t1={t1}: dL/dθ");
        assert_eq!(served.meter.nfe_backward, direct.meter.nfe_backward, "t1={t1}");
        assert_eq!(served.meter.vjp_calls, direct.meter.vjp_calls, "t1={t1}");
        assert_eq!(resp.stats.batch_size, 3, "t1={t1}: co-batched with all three");
    }
}

/// Per-sample starts: requests with identical dynamics/solver/tolerance but
/// three **distinct `t0` values** (and mixed endpoints) now share a key —
/// `t0` left the `BatchKey` — and execute as ONE `integrate_batch_tspans`
/// call (dispatch accounting: one executed batch of size 3, exact
/// stage-sweep counts with dyadic spans, zero scalar entry points), with
/// every response bit-identical to its direct single-request solve.
#[test]
fn mixed_start_batch_runs_once_and_matches_direct() {
    let vdp = VanDerPol::new(0.5);
    let (f, scalar_evals, batch_evals, _, _) = EntryCounting::new(vdp.clone());
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("vdp", f)
        .config(test_config(16, 64, 1))
        .clock(clock)
        .start();

    // Dyadic step, starts and endpoints: exact per-sample step counts
    // 16 / 24 / 16; rounds = the deepest sample's 24.
    let spans = [(0.0f64, 1.0f64), (0.5, 2.0), (1.0, 2.0)];
    let z0s: Vec<Vec<f32>> = (0..3).map(|i| vec![0.3 * i as f32 - 0.4, 0.5]).collect();
    let handles: Vec<_> = spans
        .iter()
        .zip(&z0s)
        .map(|(&(t0, t1), z0)| {
            server
                .submit(SolveRequest::fixed("vdp", t0, t1, z0.clone(), 0.0625).unwrap())
                .unwrap()
        })
        .collect();
    server.drain();

    let m = server.metrics();
    assert_eq!(m.batches, 1, "three start times must execute as ONE batch");
    assert_eq!(m.batch_sizes[3], 1);
    assert_eq!(
        scalar_evals.load(std::sync::atomic::Ordering::SeqCst),
        0,
        "no scalar fallback: the batch ran through integrate_batch_tspans alone"
    );
    assert_eq!(batch_evals.load(std::sync::atomic::Ordering::SeqCst), 4 * 24);

    let opts = IntegrateOpts::fixed(0.0625);
    for ((h, &(t0, t1)), z0) in handles.into_iter().zip(&spans).zip(&z0s) {
        let resp = h.wait().unwrap();
        let direct = integrate(&vdp, t0, t1, z0, tableau::rk4(), &opts).unwrap();
        assert_eq!(resp.z_t1(), direct.last().unwrap(), "span [{t0},{t1}]: served != direct");
        assert_eq!(resp.stats.nfe, direct.nfe, "span [{t0},{t1}]: NFE accounting");
        assert_eq!(resp.stats.steps, direct.len(), "span [{t0},{t1}]: steps");
        assert_eq!(resp.stats.batch_size, 3, "span [{t0},{t1}]: co-batched with all three");
    }
}

/// Dynamics with a panic landmine: evaluating a state with `z[0]` above the
/// threshold panics (user dynamics are arbitrary trait impls).
struct PanickyAbove(f32);

impl OdeFunc for PanickyAbove {
    fn dim(&self) -> usize {
        2
    }
    fn eval(&self, _t: f64, z: &[f32], dz: &mut [f32]) {
        assert!(z[0] <= self.0, "landmine: z[0]={} above {}", z[0], self.0);
        dz[0] = -0.5 * z[0];
        dz[1] = -0.5 * z[1];
    }
    fn vjp(&self, _t: f64, _z: &[f32], w: &[f32], wjz: &mut [f32], _wjp: &mut [f32]) {
        wjz[0] = -0.5 * w[0];
        wjz[1] = -0.5 * w[1];
    }
}

/// A panicking dynamics must not kill the worker (which would hang every
/// co-batched caller, leak admission slots, and deadlock drain/shutdown):
/// the panicking sample fails alone, its healthy neighbor answers, and the
/// server keeps serving afterwards.
#[test]
fn panicking_sample_is_contained_and_isolated() {
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("mine", PanickyAbove(5.0))
        .config(test_config(16, 64, 1))
        .clock(clock)
        .start();
    let mk = |z0: Vec<f32>| SolveRequest::fixed("mine", 0.0, 1.0, z0, 0.1).unwrap();
    let good = server.submit(mk(vec![0.5, 1.0])).unwrap();
    let bad = server.submit(mk(vec![9.0, 0.0])).unwrap(); // first eval panics
    server.drain();
    let good = good.wait();
    let bad = bad.wait();
    assert!(good.is_ok(), "healthy neighbor lost to a co-batched panic: {good:?}");
    match bad {
        Err(ServeError::Solver(msg)) => assert!(msg.contains("panic"), "{msg}"),
        other => panic!("panicking sample must fail with Solver: {other:?}"),
    }
    // The single worker survived; the server still serves.
    let h = server.submit(mk(vec![1.0, -1.0])).unwrap();
    server.drain();
    assert!(h.wait().is_ok(), "worker died on the panic");
}

/// A poison request (solver failure) must not take down its co-batched
/// neighbors: the healthy samples still answer, the poison one reports a
/// solver error.
#[test]
fn poison_sample_is_isolated_from_its_batch() {
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("vdp", VanDerPol::new(5.0))
        .config(test_config(16, 64, 1))
        .clock(clock)
        .start();
    // The huge initial state overflows `y1²` to infinity, so its solve
    // rejects every trial down to step-size underflow; the tame state
    // co-batched under the same key must still answer.
    let mk = |z0: Vec<f32>| SolveRequest::adaptive("vdp", 0.0, 4.0, z0, 1e-9, 1e-12).unwrap();
    let good = server.submit(mk(vec![0.05, 0.0])).unwrap();
    let bad = server.submit(mk(vec![f32::MAX.sqrt(), 1.0])).unwrap();
    server.drain();
    let good = good.wait();
    let bad = bad.wait();
    assert!(good.is_ok(), "healthy neighbor failed: {good:?}");
    assert!(matches!(bad, Err(ServeError::Solver(_))), "poison must fail alone: {bad:?}");
}

/// Dense-output acceptance property: across dynamics × {fixed, adaptive},
/// every served observation grid is bit-identical to building a
/// [`DenseOutput`] over the direct scalar solve and calling `eval` at each
/// grid time, and the endpoint matches too. The batch engine's per-sample
/// bit-equality plus the worker's dense-policy override make this exact,
/// not approximate.
#[test]
fn served_observations_match_direct_dense_eval() {
    let vdp = VanDerPol::new(0.5);
    let lin = Linear::new(-0.3, 3);
    let server = SolveServer::builder()
        .register("vdp", vdp.clone())
        .register("linear", lin.clone())
        .config(test_config(8, 64, 2))
        .start();

    let grid = vec![0.1, 0.33, 0.5, 0.999, 1.4];
    let mut rng = Pcg64::seed(7);
    // (dynamics, dim, fixed step or None=adaptive) × 2 samples each, all
    // submitted up front so compatible pairs co-batch.
    let combos: [(&str, usize, Option<f64>); 4] =
        [("vdp", 2, Some(0.05)), ("vdp", 2, None), ("linear", 3, Some(0.05)), ("linear", 3, None)];
    let mut cases = Vec::new();
    for &(name, dim, h) in &combos {
        for _ in 0..2 {
            let z0: Vec<f32> = (0..dim).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let b = SolveRequest::builder(name).span(0.0, 1.5).state(z0).observe_at(grid.clone());
            let b = match h {
                Some(h) => b.fixed(h),
                None => b.adaptive(1e-6, 1e-8),
            };
            let req = b.build().unwrap();
            let handle = server.submit(req.clone()).unwrap();
            cases.push((name, req, handle));
        }
    }
    server.drain();

    for (i, (name, req, handle)) in cases.into_iter().enumerate() {
        let resp = handle.wait().unwrap();
        // The reference: a direct scalar solve with a dense store and a
        // DenseOutput interpolant evaluated pointwise on the same grid.
        let mut opts = req.opts();
        opts.ckpt = CkptPolicy::from_budget(0);
        let (z_t1_direct, direct): (Vec<f32>, Vec<Vec<f32>>) = if name == "vdp" {
            let traj = integrate(&vdp, req.t0, req.t1, &req.z0, req.tab, &opts).unwrap();
            let dense = DenseOutput::new(&vdp, &traj);
            (traj.last().unwrap().to_vec(), grid.iter().map(|&t| dense.eval(t)).collect())
        } else {
            let traj = integrate(&lin, req.t0, req.t1, &req.z0, req.tab, &opts).unwrap();
            let dense = DenseOutput::new(&lin, &traj);
            (traj.last().unwrap().to_vec(), grid.iter().map(|&t| dense.eval(t)).collect())
        };
        assert_eq!(resp.z_t1(), z_t1_direct, "case {i} ({name}): endpoint");
        let zs = resp.observations().expect("observation payload");
        assert_eq!(zs.len(), grid.len(), "case {i} ({name}): grid length");
        for ((&t, served), want) in grid.iter().zip(zs).zip(&direct) {
            let got_bits: Vec<u32> = served.iter().map(|v| v.to_bits()).collect();
            let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "case {i} ({name}): observation at t={t}");
        }
    }
}

/// A linear dynamics that advances a shared [`ManualClock`] on every
/// evaluation: execution order becomes a deterministic function of batch
/// scheduling, so queue-wait metrics can be asserted exactly, without
/// sleeps.
struct TickingLinear {
    inner: Linear,
    clock: Arc<ManualClock>,
    tick: Duration,
}

impl OdeFunc for TickingLinear {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        self.clock.advance(self.tick);
        self.inner.eval(t, z, dz);
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        self.inner.vjp(t, z, w, wjz, wjp);
    }
}

/// Fairness regression (the QoS acceptance test): a tenant flooding the
/// queue with many batches must not starve a calm tenant. Deficit
/// round-robin interleaves the calm tenant's single batch right after the
/// hot tenant's first one, so the calm tenant's per-key p99 queue wait
/// stays strictly below the hot tenant's own — under plain FIFO emission
/// (all hot batches first) the inequality flips.
#[test]
fn flooding_tenant_does_not_starve_calm_tenant() {
    let clock = ManualClock::new();
    let tick = Duration::from_millis(1);
    let mk_dyn = || TickingLinear { inner: Linear::new(-0.5, 2), clock: clock.clone(), tick };
    let mut cfg = test_config(64, 64, 1);
    // One hot batch per DRR visit: the calm tenant flushes in round one.
    cfg.quota_quantum = 2;
    cfg.quota_max_deficit = 2; // clamps up to max_batch internally
    let server = SolveServer::builder()
        .register("hot", mk_dyn())
        .register("calm", mk_dyn())
        .config(cfg)
        .clock(clock.clone())
        .start();

    // Hot tenant: 6 requests across 3 distinct fixed steps = 3 batch keys
    // of 2 samples each. Calm tenant: one batch of 2. All submitted at
    // virtual time zero; nothing flushes (max_batch 64, huge deadline)
    // until drain() emits everything in DRR order onto the single worker.
    let mut handles = Vec::new();
    for &h in &[0.25f64, 0.125, 0.0625] {
        for i in 0..2 {
            let req = SolveRequest::fixed("hot", 0.0, 1.0, vec![0.1 * i as f32, 1.0], h).unwrap();
            handles.push(server.submit(req).unwrap());
        }
    }
    for i in 0..2 {
        let req =
            SolveRequest::fixed("calm", 0.0, 1.0, vec![0.2 * i as f32, -1.0], 0.25).unwrap();
        handles.push(server.submit(req).unwrap());
    }
    server.drain();
    for (i, h) in handles.into_iter().enumerate() {
        assert!(h.wait().is_ok(), "request {i} starved or failed");
    }

    let m = server.metrics();
    let wait = |key: &str| {
        m.per_key_queue_wait
            .iter()
            .find(|(k, _)| k == key)
            .unwrap_or_else(|| panic!("no per-key queue-wait for {key}"))
            .1
            .clone()
    };
    let hot = wait("hot");
    let calm = wait("calm");
    assert_eq!(hot.count, 6, "all hot requests recorded");
    assert_eq!(calm.count, 2, "all calm requests recorded");
    // DRR emission order is hot₁, calm, hot₂, hot₃; every eval ticks the
    // clock, so the calm batch waits only behind hot₁ while the last hot
    // batch waits behind everything — the calm tenant's p99 must sit
    // strictly below the flooding tenant's.
    assert!(
        calm.p99_ms < hot.p99_ms,
        "calm tenant starved: calm p99 {} ms >= hot p99 {} ms",
        calm.p99_ms,
        hot.p99_ms
    );
    assert!(calm.max_ms < hot.max_ms, "calm {} vs hot {}", calm.max_ms, hot.max_ms);
}

/// Deterministic tracing under [`ManualClock`]: a scripted 3-request
/// mixed-lane scenario (two interactive requests co-batch, one batch-lane
/// request rides alone) must produce *exactly* the expected span tree per
/// trace — names, parent edges, attributes, and nanosecond-exact
/// durations. The clock never advances during execution (plain `Linear`
/// dynamics), so every post-submit timestamp lands on the drain instant
/// and queue waits equal the scripted submission offsets.
#[test]
fn traced_mixed_lane_batch_yields_exact_span_trees() {
    let clock = ManualClock::new();
    let server = SolveServer::builder()
        .register("linear", Linear::new(-0.5, 2))
        .config(test_config(64, 64, 1))
        .clock(clock.clone())
        .start();

    let ids: Vec<_> = (1..=3u64).map(|i| obs::mint(Duration::from_nanos(i))).collect();
    let mk = |i: usize, lane: Lane, id: obs::TraceId| {
        let mut req =
            SolveRequest::fixed("linear", 0.0, 1.0, vec![0.1 * (i + 1) as f32, -1.0], 0.25)
                .unwrap();
        req.lane = lane;
        req.trace = Some(TraceCtx::root(id));
        req
    };
    // Script: submissions at 1/2/3 ms of virtual time; nothing flushes
    // (max_batch 64, huge deadline) until drain() at 10 ms.
    clock.set(Duration::from_millis(1));
    let a = server.submit(mk(0, Lane::Interactive, ids[0])).unwrap();
    clock.set(Duration::from_millis(2));
    let b = server.submit(mk(1, Lane::Interactive, ids[1])).unwrap();
    clock.set(Duration::from_millis(3));
    let c = server.submit(mk(2, Lane::Batch, ids[2])).unwrap();
    clock.set(Duration::from_millis(10));
    server.drain();
    let (ra, rb, rc) = (a.wait().unwrap(), b.wait().unwrap(), c.wait().unwrap());

    let ms = |n: u64| n * 1_000_000;
    let check = |id: obs::TraceId, submitted_ms: u64, lane: Lane, size: u64, nfe: usize| {
        let spans = obs::global().take(id);
        let names: Vec<&str> = spans.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            vec![obs::QUEUE_WAIT, obs::BATCH_FORM, obs::SOLVE, obs::FORWARD],
            "span tree for trace {}",
            id.to_hex()
        );
        let (qw, bf, solve, fwd) = (&spans[0], &spans[1], &spans[2], &spans[3]);
        for s in &spans {
            assert_eq!(s.trace, id.0, "all spans join the request's trace");
        }
        // Queue wait runs from the scripted submission instant to the
        // drain-triggered flush — exact to the nanosecond.
        assert_eq!((qw.start_ns, qw.end_ns), (ms(submitted_ms), ms(10)), "queue wait");
        assert_eq!(qw.get_attr("lane"), Some(lane as u64));
        assert_eq!(qw.get_attr("deferred"), Some(0), "light traffic: no DRR deferral");
        assert_eq!((bf.start_ns, bf.end_ns), (ms(10), ms(10)), "batch forms at drain");
        assert_eq!(bf.get_attr("reason"), Some(FlushReason::Drain as u64));
        assert_eq!(bf.get_attr("size"), Some(size));
        assert_eq!((solve.start_ns, solve.end_ns), (ms(10), ms(10)));
        assert_eq!(solve.get_attr("batch_size"), Some(size));
        assert_eq!(qw.parent, 0, "phase spans parent to the root context");
        assert_eq!(fwd.parent, solve.span, "forward nests under solve");
        assert_eq!(fwd.get_attr("nfe"), Some(nfe as u64));
        // rk4 over t ∈ [0, 1] at h = 0.25: 4 rounds, 4 stage sweeps each.
        assert_eq!(fwd.get_attr("rounds"), Some(4));
        assert_eq!(fwd.get_attr("sweeps"), Some(16));
    };
    check(ids[0], 1, Lane::Interactive, 2, ra.stats.nfe);
    check(ids[1], 2, Lane::Interactive, 2, rb.stats.nfe);
    check(ids[2], 3, Lane::Batch, 1, rc.stats.nfe);
}
