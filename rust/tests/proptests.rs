//! Property-based tests on coordinator invariants (hand-rolled generators
//! over the crate's deterministic PCG64 — the offline build vendors no
//! proptest). Each property sweeps many random cases; failures print the
//! case seed for replay.

use nodal::grad::{aca_backward, aca_backward_batch, naive_backward, step_vjp};
use nodal::ode::analytic::{ConvFlow, Linear, ThreeBody, VanDerPol};
use nodal::ode::{
    integrate, integrate_batch, integrate_batch_spans, rk_step, tableau, IntegrateOpts, OdeFunc,
    StepScratch, Tableau,
};
use nodal::util::Pcg64;

const CASES: usize = 40;

fn tabs() -> [&'static Tableau; 6] {
    [
        tableau::euler(),
        tableau::rk2(),
        tableau::rk4(),
        tableau::heun_euler(),
        tableau::rk23(),
        tableau::dopri5(),
    ]
}

/// Property: the integration grid is strictly monotone, starts at t0, ends
/// exactly at t1, and checkpoint counts are consistent — for random spans,
/// directions, tolerances and solvers.
#[test]
fn prop_grid_monotone_and_exact_endpoints() {
    let mut rng = Pcg64::seed(101);
    for case in 0..CASES {
        let tab = tabs()[rng.below(6)];
        let t0 = rng.range(-3.0, 3.0);
        let adaptive = tab.adaptive() && rng.uniform() < 0.7;
        // Reverse-time van der Pol is anti-damped: integrating it with a
        // *fixed* step genuinely blows up, which is a property of the
        // dynamics, not of the grid bookkeeping under test — so backward
        // spans only exercise the adaptive path (which also blows up for
        // long spans; keep them short).
        let backward = adaptive && rng.uniform() < 0.4;
        let span_mag = if backward { rng.range(0.3, 2.0) } else { rng.range(0.3, 8.0) };
        let span = span_mag * if backward { -1.0 } else { 1.0 };
        let t1 = t0 + span;
        let mu = rng.range(0.1, 1.5) as f32;
        let f = VanDerPol::new(mu);
        let z0 = [rng.range(-2.0, 2.0) as f32, rng.range(-2.0, 2.0) as f32];
        let opts = if adaptive {
            IntegrateOpts::with_tol(10f64.powf(rng.range(-8.0, -3.0)), 1e-9)
        } else {
            IntegrateOpts::fixed(rng.range(0.005, 0.05))
        };
        let traj = match integrate(&f, t0, t1, &z0, tab, &opts) {
            Ok(t) => t,
            // Reverse-time van der Pol can blow up to step-size underflow
            // from initial states outside the limit cycle — a property of
            // the dynamics, not of the grid bookkeeping under test.
            Err(_) if backward => continue,
            Err(e) => panic!("case {case}: {e}"),
        };
        assert_eq!(traj.ts[0], t0, "case {case}");
        assert_eq!(*traj.ts.last().unwrap(), t1, "case {case} ({})", tab.name);
        let dir = span.signum();
        for w in traj.ts.windows(2) {
            assert!((w[1] - w[0]) * dir > 0.0, "case {case}: non-monotone {w:?}");
        }
        assert_eq!(traj.store.len(), traj.ts.len(), "case {case}");
        assert_eq!(traj.errs.len(), traj.len(), "case {case}");
    }
}

/// Property: replaying the saved checkpoints through the step function
/// reproduces the stored forward trajectory bit-for-bit (ACA's core
/// guarantee: reverse-mode trajectory == forward-mode trajectory).
#[test]
fn prop_checkpoint_replay_is_bit_exact() {
    let mut rng = Pcg64::seed(202);
    for case in 0..CASES {
        let tab = tabs()[3 + rng.below(3)]; // adaptive ones
        let f = VanDerPol::new(rng.range(0.1, 2.0) as f32);
        let z0 = [rng.range(-2.0, 2.0) as f32, rng.range(-1.0, 1.0) as f32];
        let opts = IntegrateOpts::with_tol(10f64.powf(rng.range(-7.0, -3.0)), 1e-9);
        let traj = integrate(&f, 0.0, rng.range(0.5, 4.0), &z0, tab, &opts).unwrap();
        let mut scratch = StepScratch::new();
        for i in 0..traj.len() {
            let mut z_next = vec![0.0f32; 2];
            rk_step(
                &f,
                tab,
                traj.ts[i],
                traj.h(i),
                traj.z(i).unwrap(),
                None,
                opts.atol,
                opts.rtol,
                &mut z_next,
                None,
                &mut scratch,
            );
            assert_eq!(
                z_next,
                traj.z(i + 1).unwrap(),
                "case {case} ({}), step {i}: replay diverged",
                tab.name
            );
        }
    }
}

/// Property: step_vjp matches central finite differences of the step map for
/// random states, step sizes and solvers (van der Pol).
#[test]
fn prop_step_vjp_matches_fd() {
    let mut rng = Pcg64::seed(303);
    for case in 0..CASES {
        let tab = tabs()[rng.below(6)];
        let f = VanDerPol::new(rng.range(0.1, 1.0) as f32);
        let t = rng.range(0.0, 2.0);
        let h = rng.range(0.02, 0.3);
        let z = [rng.range(-1.5, 1.5) as f32, rng.range(-1.5, 1.5) as f32];
        let lam = [rng.range(-1.0, 1.0) as f32, rng.range(-1.0, 1.0) as f32];
        let mut dtheta: Vec<f32> = vec![];
        let out = step_vjp(&f, tab, t, h, &z, &lam, &mut dtheta, false);

        let step = |zz: &[f32]| -> f64 {
            let mut y = [0.0f32; 2];
            let mut s = StepScratch::new();
            rk_step(&f, tab, t, h, zz, None, 1e-9, 1e-9, &mut y, None, &mut s);
            lam.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        for i in 0..2 {
            let eps = 1e-3f32;
            let mut zp = z;
            zp[i] += eps;
            let mut zm = z;
            zm[i] -= eps;
            let fd = (step(&zp) - step(&zm)) / (2.0 * eps as f64);
            let got = out.dz[i] as f64;
            assert!(
                (got - fd).abs() < 5e-3 * fd.abs().max(1.0),
                "case {case} ({}): dz[{i}] {got} vs fd {fd}",
                tab.name
            );
        }
    }
}

/// Property: for fixed-step solves, naive == ACA exactly (no step-size
/// search to differentiate through — paper Sec 3.3).
#[test]
fn prop_fixed_step_naive_equals_aca() {
    let mut rng = Pcg64::seed(404);
    for case in 0..CASES {
        let tab = tabs()[rng.below(6)];
        let f = VanDerPol::new(rng.range(0.1, 1.5) as f32);
        let z0 = [rng.range(-2.0, 2.0) as f32, rng.range(-1.0, 1.0) as f32];
        let opts = IntegrateOpts::fixed(rng.range(0.02, 0.1));
        let traj = integrate(&f, 0.0, 1.5, &z0, tab, &opts).unwrap();
        let lam = [rng.range(-1.0, 1.0) as f32, rng.range(-1.0, 1.0) as f32];
        let a = aca_backward(&f, tab, &traj, &lam);
        let n = naive_backward(&f, tab, &traj, &lam, &opts);
        assert_eq!(a.dl_dz0, n.dl_dz0, "case {case} ({})", tab.name);
    }
}

/// Property: gradient linearity — backward with λ1 + λ2 equals backward(λ1)
/// + backward(λ2) (the step adjoint is linear in the cotangent).
#[test]
fn prop_backward_linear_in_cotangent() {
    let mut rng = Pcg64::seed(505);
    for case in 0..20 {
        let tab = tableau::dopri5();
        let f = VanDerPol::new(0.5);
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let traj = integrate(&f, 0.0, 2.0, &[1.5, -0.5], tab, &opts).unwrap();
        let l1 = [rng.normal_f32(), rng.normal_f32()];
        let l2 = [rng.normal_f32(), rng.normal_f32()];
        let sum = [l1[0] + l2[0], l1[1] + l2[1]];
        let g1 = aca_backward(&f, tab, &traj, &l1);
        let g2 = aca_backward(&f, tab, &traj, &l2);
        let gs = aca_backward(&f, tab, &traj, &sum);
        for i in 0..2 {
            let lin = g1.dl_dz0[i] + g2.dl_dz0[i];
            assert!(
                (gs.dl_dz0[i] - lin).abs() < 1e-4 * lin.abs().max(1.0),
                "case {case}: {} vs {}",
                gs.dl_dz0[i],
                lin
            );
        }
    }
}

/// Property: solver convergence order — halving the fixed step shrinks the
/// endpoint error by ~2^order on the linear system.
#[test]
fn prop_convergence_order() {
    for tab in tabs() {
        let f = Linear::new(-1.0, 1);
        let exact = (-2.0f64).exp();
        let err_at = |h: f64| -> f64 {
            let traj = integrate(&f, 0.0, 2.0, &[1.0], tab, &IntegrateOpts::fixed(h)).unwrap();
            (traj.last().unwrap()[0] as f64 - exact).abs().max(1e-12)
        };
        let (e1, e2) = (err_at(0.1), err_at(0.05));
        let rate = (e1 / e2).log2();
        // f32 round-off floors the high-order methods; only require the rate
        // where truncation still dominates.
        if e2 > 1e-6 {
            assert!(
                rate > tab.order as f64 - 0.8,
                "{}: rate {rate} < order {}",
                tab.name,
                tab.order
            );
        }
    }
}

/// Property: batcher covers every sample exactly once per epoch.
#[test]
fn prop_permutation_batching_covers_all() {
    let mut rng = Pcg64::seed(606);
    for _ in 0..20 {
        let n = 1 + rng.below(500);
        let b = 1 + rng.below(64);
        let perm = rng.permutation(n);
        let mut seen = vec![false; n];
        for chunk in perm.chunks(b) {
            for &i in chunk {
                assert!(!seen[i], "duplicate sample");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "missing samples");
    }
}

/// Property: trajectory memory accounting equals the analytic formula —
/// full accounting: states (f32) + times + step sizes + error norms (f64
/// each); no trials on a fixed-step run.
#[test]
fn prop_checkpoint_bytes_formula() {
    let mut rng = Pcg64::seed(707);
    for _ in 0..20 {
        let dim = 1 + rng.below(20);
        let f = Linear::new(-0.3, dim);
        let z0: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
        let traj =
            integrate(&f, 0.0, 1.0, &z0, tableau::rk4(), &IntegrateOpts::fixed(0.05)).unwrap();
        let n_pts = traj.len() + 1;
        let steps = traj.len();
        assert_eq!(
            traj.checkpoint_bytes(),
            n_pts * dim * 4 + n_pts * 8 + steps * 8 + steps * 8
        );
    }
}

/// The four analytic dynamics, all of which now override
/// [`OdeFunc::eval_batch`]; boxed so one loop sweeps them uniformly.
fn all_dynamics() -> [(&'static str, Box<dyn OdeFunc>); 4] {
    [
        ("linear", Box::new(Linear::new(-0.6, 3)) as Box<dyn OdeFunc>),
        ("vdp", Box::new(VanDerPol::new(0.4))),
        // Light masses: with solar masses and G = 4π², random initial
        // conditions free-fall into close encounters within ~0.1 yr and the
        // adaptive solve (correctly) grinds to tiny steps — a property of
        // the physics, not of the batching equivalence under test.
        ("threebody", Box::new(ThreeBody::new([1e-3, 8e-4, 1.2e-3]))),
        ("convflow", Box::new(ConvFlow::random(4, 4, 5, 0.4))),
    ]
}

/// Property: every analytic dynamics' `eval_batch` override is bit-identical
/// to looping `eval` per sample — the contract `integrate_batch`'s
/// scalar-equivalence guarantee rests on, for all four dynamics.
#[test]
fn prop_eval_batch_matches_scalar_all_dynamics() {
    let mut rng = Pcg64::seed(909);
    for (name, f) in all_dynamics() {
        let d = f.dim();
        for case in 0..CASES {
            let n = 1 + rng.below(9);
            let ts: Vec<f64> = (0..n).map(|_| rng.range(-2.0, 2.0)).collect();
            let zs: Vec<f32> = (0..n * d).map(|_| rng.range(-1.5, 1.5) as f32).collect();
            let mut batched = vec![0.0f32; n * d];
            f.eval_batch(&ts, &zs, &mut batched);
            let mut scalar = vec![0.0f32; d];
            for i in 0..n {
                f.eval(ts[i], &zs[i * d..(i + 1) * d], &mut scalar);
                assert_eq!(
                    &batched[i * d..(i + 1) * d],
                    &scalar[..],
                    "{name} case {case}: sample {i} of {n} diverged"
                );
            }
        }
    }
}

/// Property: full batched solves match per-sample scalar solves on all four
/// analytic dynamics (each with its own `eval_batch` override) — fixed-step
/// bit-exact including grids and checkpoints, adaptive endpoints ≤ 1e-6
/// relative, and per-sample nfe/rejection accounting identical.
#[test]
fn prop_batch_solves_match_scalar_all_dynamics() {
    let mut rng = Pcg64::seed(1010);
    let rel_close =
        |a: f32, b: f32| -> bool { (a - b).abs() as f64 <= 1e-6 * (b.abs() as f64).max(1.0) };
    for (name, f) in all_dynamics() {
        let d = f.dim();
        for case in 0..6 {
            let fixed = case % 2 == 0;
            let b = [1usize, 3, 5][case % 3];
            let tab = if fixed { tableau::rk4() } else { tableau::dopri5() };
            // Short spans keep the stiff cases (three-body close encounters)
            // inside solver reach at every random initial condition.
            let t1 = rng.range(0.2, 0.8);
            let z0: Vec<f32> = (0..b * d).map(|_| rng.range(-1.2, 1.2) as f32).collect();
            let opts = if fixed {
                IntegrateOpts::fixed(rng.range(0.01, 0.04))
            } else {
                IntegrateOpts::with_tol(1e-6, 1e-8)
            };
            let bt = integrate_batch(&*f, 0.0, t1, &z0, tab, &opts).unwrap();
            for i in 0..b {
                let traj = integrate(&*f, 0.0, t1, &z0[i * d..(i + 1) * d], tab, &opts).unwrap();
                let ctx = format!("{name} case {case} B={b} sample {i}");
                assert_eq!(bt.steps(i), traj.len(), "{ctx}: steps");
                assert_eq!(bt.tracks[i].nfe, traj.nfe, "{ctx}: nfe");
                assert_eq!(bt.tracks[i].n_rejected, traj.n_rejected, "{ctx}: rejected");
                if fixed {
                    assert_eq!(bt.tracks[i].ts, traj.ts, "{ctx}: grid");
                    for k in 0..=traj.len() {
                        assert_eq!(bt.z(i, k), traj.z(k).unwrap(), "{ctx}: checkpoint {k}");
                    }
                } else {
                    for (a, e) in bt.last(i).iter().zip(traj.last().unwrap()) {
                        assert!(rel_close(*a, *e), "{ctx}: endpoint {a} vs {e}");
                    }
                }
            }
        }
    }
}

/// Property: the shared-stage batched backward pass is **bit-equal** to the
/// scalar `aca_backward` over the same recorded trajectory — `dL/dz0`,
/// `dL/dθ`, and every meter — for all four analytic dynamics (each with its
/// own `eval_batch`/`vjp_batch` override), B ∈ {1, 3, 8}, fixed-step and
/// adaptive, including mismatched per-sample step counts (the retirement
/// path of the active-set loop).
///
/// The scalar reference reads the *same* checkpoints
/// (`BatchTrajectory::to_trajectory`), so this pins the reverse sweep itself
/// — stage recomputation, ŵ-sweep, dθ accumulation order, dead-stage
/// skipping — independent of the (already-pinned) forward equivalence.
#[test]
fn prop_shared_stage_backward_bit_equals_scalar_all_dynamics() {
    let mut rng = Pcg64::seed(1212);
    let mut saw_mismatched_steps = false;
    for (name, f) in all_dynamics() {
        let d = f.dim();
        for case in 0..6 {
            let fixed = case % 2 == 0;
            let b = [1usize, 3, 8][case % 3];
            let tab = if fixed { tableau::rk4() } else { tableau::dopri5() };
            let t1 = rng.range(0.2, 0.8);
            // Spread magnitudes so adaptive per-sample step counts differ
            // (exercises retirement); short spans keep the stiff dynamics
            // (three-body close encounters) inside solver reach.
            let z0: Vec<f32> = (0..b * d)
                .map(|i| {
                    let scale = if (i / d) % 2 == 0 { 1.0 } else { 0.5 };
                    rng.range(-1.2, 1.2) as f32 * scale
                })
                .collect();
            let opts = if fixed {
                IntegrateOpts::fixed(rng.range(0.01, 0.04))
            } else {
                IntegrateOpts::with_tol(1e-6, 1e-8)
            };
            let bt = integrate_batch(&*f, 0.0, t1, &z0, tab, &opts).unwrap();
            let lam: Vec<f32> = (0..b * d).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let gb = aca_backward_batch(&*f, tab, &bt, &lam);
            let step_counts: Vec<usize> = (0..b).map(|i| bt.steps(i)).collect();
            saw_mismatched_steps |= step_counts.windows(2).any(|w| w[0] != w[1]);
            for i in 0..b {
                let traj = bt.to_trajectory(i);
                let ga = aca_backward(&*f, tab, &traj, &lam[i * d..(i + 1) * d]);
                let ctx = format!("{name} case {case} B={b} sample {i} ({})", tab.name);
                assert_eq!(gb[i].dl_dz0, ga.dl_dz0, "{ctx}: dl_dz0");
                assert_eq!(gb[i].dl_dtheta, ga.dl_dtheta, "{ctx}: dl_dtheta");
                assert_eq!(gb[i].meter.nfe_forward, ga.meter.nfe_forward, "{ctx}: nfe_f");
                assert_eq!(gb[i].meter.nfe_backward, ga.meter.nfe_backward, "{ctx}: nfe_b");
                assert_eq!(gb[i].meter.vjp_calls, ga.meter.vjp_calls, "{ctx}: vjps");
                assert_eq!(gb[i].meter.graph_depth, ga.meter.graph_depth, "{ctx}: depth");
                assert_eq!(gb[i].meter.n_steps, ga.meter.n_steps, "{ctx}: steps");
                assert_eq!(gb[i].meter.n_rejected, ga.meter.n_rejected, "{ctx}: rejected");
                assert_eq!(
                    gb[i].meter.checkpoint_bytes,
                    ga.meter.checkpoint_bytes,
                    "{ctx}: bytes"
                );
            }
        }
    }
    assert!(
        saw_mismatched_steps,
        "sweep never exercised the retirement path (all step counts equal)"
    );
}

/// Property: per-sample spans — `integrate_batch_spans` with every
/// sample's `t1` drawn independently, chained into `aca_backward_batch` —
/// reproduce scalar `integrate` + `aca_backward` over each sample's own
/// span **bit-for-bit**: forward finals (and full grids), `dl_dz0`,
/// `dl_dtheta`, and all per-sample meters, for all four analytic dynamics,
/// B ∈ {1, 3, 8}, fixed-step and adaptive. Each sample derives its span
/// geometry (direction, endpoint epsilon, step clamps) from its own `t1`
/// exactly as a scalar solve would, so mixed spans add no tolerance at all.
#[test]
fn prop_mixed_span_batch_matches_scalar_all_dynamics() {
    let mut rng = Pcg64::seed(1313);
    let mut saw_mixed_spans = false;
    for (name, f) in all_dynamics() {
        let d = f.dim();
        for case in 0..6 {
            let fixed = case % 2 == 0;
            let b = [1usize, 3, 8][case % 3];
            let tab = if fixed { tableau::rk4() } else { tableau::dopri5() };
            // Per-sample endpoints, drawn independently; short spans keep
            // the stiff dynamics (three-body close encounters) inside
            // solver reach at every random initial condition.
            let t1s: Vec<f64> = (0..b).map(|_| rng.range(0.2, 0.8)).collect();
            saw_mixed_spans |= t1s.windows(2).any(|w| w[0] != w[1]);
            let z0: Vec<f32> = (0..b * d).map(|_| rng.range(-1.2, 1.2) as f32).collect();
            let opts = if fixed {
                IntegrateOpts::fixed(rng.range(0.01, 0.04))
            } else {
                IntegrateOpts::with_tol(1e-6, 1e-8)
            };
            let bt = integrate_batch_spans(&*f, 0.0, &t1s, &z0, tab, &opts).unwrap();
            let lam: Vec<f32> = (0..b * d).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let gb = aca_backward_batch(&*f, tab, &bt, &lam);
            for (i, &t1) in t1s.iter().enumerate() {
                let traj = integrate(&*f, 0.0, t1, &z0[i * d..(i + 1) * d], tab, &opts).unwrap();
                let ga = aca_backward(&*f, tab, &traj, &lam[i * d..(i + 1) * d]);
                let ctx = format!("{name} case {case} B={b} sample {i} t1={t1}");
                assert_eq!(bt.tracks[i].ts, traj.ts, "{ctx}: grid");
                assert_eq!(bt.tracks[i].hs, traj.hs, "{ctx}: step sizes");
                assert_eq!(bt.last(i), traj.last().unwrap(), "{ctx}: forward final");
                assert_eq!(*bt.tracks[i].ts.last().unwrap(), t1, "{ctx}: lands on its t1");
                assert_eq!(bt.tracks[i].nfe, traj.nfe, "{ctx}: nfe");
                assert_eq!(bt.tracks[i].n_rejected, traj.n_rejected, "{ctx}: rejected");
                assert_eq!(bt.checkpoint_bytes(i), traj.checkpoint_bytes(), "{ctx}: bytes");
                assert_eq!(gb[i].dl_dz0, ga.dl_dz0, "{ctx}: dl_dz0");
                assert_eq!(gb[i].dl_dtheta, ga.dl_dtheta, "{ctx}: dl_dtheta");
                assert_eq!(gb[i].meter.nfe_forward, ga.meter.nfe_forward, "{ctx}: nfe_f");
                assert_eq!(gb[i].meter.nfe_backward, ga.meter.nfe_backward, "{ctx}: nfe_b");
                assert_eq!(gb[i].meter.vjp_calls, ga.meter.vjp_calls, "{ctx}: vjps");
                assert_eq!(gb[i].meter.graph_depth, ga.meter.graph_depth, "{ctx}: depth");
                assert_eq!(gb[i].meter.n_steps, ga.meter.n_steps, "{ctx}: steps");
                assert_eq!(gb[i].meter.n_rejected, ga.meter.n_rejected, "{ctx}: rej");
                assert_eq!(
                    gb[i].meter.checkpoint_bytes,
                    ga.meter.checkpoint_bytes,
                    "{ctx}: meter bytes"
                );
            }
        }
    }
    assert!(saw_mixed_spans, "sweep never drew two distinct spans in one batch");
}

/// Property: a memory-budgeted checkpoint store changes *where* states
/// live, never a result bit. For all four analytic dynamics × B ∈ {1, 3, 8}
/// × fixed/adaptive × policies {dense, every-4th, ~25%-of-dense byte
/// budget}: grids, step sizes, final states, `dl_dz0`/`dl_dtheta` and every
/// classic meter are **bit-equal** to the dense store (batched and scalar),
/// thinned stores actually replay (`nfe_replay > 0`) and hold strictly
/// fewer checkpoint bytes, and the budgeted store's peak state bytes never
/// exceed the budget **mid-solve** (up to the documented 2-anchor floor —
/// the initial state and the tail always fit).
#[test]
fn prop_budgeted_ckpt_grads_bit_equal_dense() {
    use nodal::ckpt::CkptPolicy;
    let mut rng = Pcg64::seed(1414);
    let mut saw_replay = false;
    for (name, f) in all_dynamics() {
        let d = f.dim();
        for case in 0..6 {
            let fixed = case % 2 == 0;
            let b = [1usize, 3, 8][case % 3];
            let tab = if fixed { tableau::rk4() } else { tableau::dopri5() };
            let t1 = rng.range(0.3, 0.8);
            let z0: Vec<f32> = (0..b * d).map(|_| rng.range(-1.2, 1.2) as f32).collect();
            let base = if fixed {
                IntegrateOpts::fixed(rng.range(0.005, 0.02))
            } else {
                IntegrateOpts::with_tol(1e-6, 1e-8)
            };
            let dense = integrate_batch(&*f, 0.0, t1, &z0, tab, &base).unwrap();
            let lam: Vec<f32> = (0..b * d).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let gd = aca_backward_batch(&*f, tab, &dense, &lam);

            // Budget: 25% of the smallest sample's dense state footprint.
            let min_states = (0..b).map(|i| dense.steps(i) + 1).min().unwrap();
            let budget = min_states * d * 4 / 4;
            let floor = 2 * d * 4; // the 2-anchor clamp (z0 + tail)
            for policy in [CkptPolicy::Dense, CkptPolicy::EveryK(4), CkptPolicy::Budgeted(budget)]
            {
                let thinning = policy != CkptPolicy::Dense;
                let opts = IntegrateOpts { ckpt: policy, ..base.clone() };
                let bt = integrate_batch(&*f, 0.0, t1, &z0, tab, &opts).unwrap();
                let gs = aca_backward_batch(&*f, tab, &bt, &lam);
                // Scalar path under the same policy, pinned via sample 0.
                let straj = integrate(&*f, 0.0, t1, &z0[..d], tab, &opts).unwrap();
                let gsc = aca_backward(&*f, tab, &straj, &lam[..d]);
                let ctx0 = format!("{name} case {case} B={b} {policy:?}");
                assert_eq!(gsc.dl_dz0, gd[0].dl_dz0, "{ctx0}: scalar dl_dz0");
                assert_eq!(gsc.dl_dtheta, gd[0].dl_dtheta, "{ctx0}: scalar dl_dtheta");
                for i in 0..b {
                    let ctx = format!("{ctx0} sample {i}");
                    assert_eq!(bt.tracks[i].ts, dense.tracks[i].ts, "{ctx}: grid");
                    assert_eq!(bt.tracks[i].hs, dense.tracks[i].hs, "{ctx}: step sizes");
                    assert_eq!(bt.last(i), dense.last(i), "{ctx}: final state");
                    assert_eq!(bt.tracks[i].nfe, dense.tracks[i].nfe, "{ctx}: nfe");
                    assert_eq!(gs[i].dl_dz0, gd[i].dl_dz0, "{ctx}: dl_dz0");
                    assert_eq!(gs[i].dl_dtheta, gd[i].dl_dtheta, "{ctx}: dl_dtheta");
                    assert_eq!(gs[i].meter.nfe_forward, gd[i].meter.nfe_forward, "{ctx}");
                    assert_eq!(gs[i].meter.nfe_backward, gd[i].meter.nfe_backward, "{ctx}");
                    assert_eq!(gs[i].meter.vjp_calls, gd[i].meter.vjp_calls, "{ctx}");
                    assert_eq!(gs[i].meter.graph_depth, gd[i].meter.graph_depth, "{ctx}");
                    assert_eq!(gs[i].meter.n_steps, gd[i].meter.n_steps, "{ctx}");
                    assert_eq!(gs[i].meter.n_rejected, gd[i].meter.n_rejected, "{ctx}");
                    if thinning {
                        assert!(
                            gs[i].meter.checkpoint_bytes <= gd[i].meter.checkpoint_bytes,
                            "{ctx}: thinned store grew"
                        );
                        if bt.steps(i) >= 8 {
                            assert!(gs[i].meter.nfe_replay > 0, "{ctx}: no replay happened");
                            saw_replay = true;
                        }
                    } else {
                        assert_eq!(
                            gs[i].meter.checkpoint_bytes,
                            gd[i].meter.checkpoint_bytes,
                            "{ctx}: dense bytes"
                        );
                        assert_eq!(gs[i].meter.nfe_replay, 0, "{ctx}: dense must not replay");
                    }
                    if policy == CkptPolicy::Budgeted(budget) {
                        assert!(
                            bt.peak_state_bytes(i) <= budget.max(floor),
                            "{ctx}: peak {} bytes over budget {budget} (floor {floor})",
                            bt.peak_state_bytes(i)
                        );
                    }
                }
            }
        }
    }
    assert!(saw_replay, "sweep never thinned enough to exercise segment replay");
}

/// Property: tracing is answer-neutral — the same request solved with and
/// without a trace context yields bit-identical payloads (final states,
/// `dl_dz0`, `dl_dtheta`, and every cost-meter field) across all four
/// analytic dynamics, fixed and adaptive, forward and gradient classes.
/// The trace context is deliberately excluded from the batch key, and no
/// solver code path may branch on it; this pins that contract.
#[test]
fn prop_tracing_on_off_is_bit_neutral_all_dynamics() {
    use nodal::obs::{self, TraceCtx};
    use nodal::serve::{Payload, ServeConfig, SolveRequest, SolveServer};
    use std::time::Duration;

    let server = SolveServer::builder()
        .register("linear", Linear::new(-0.6, 3))
        .register("vdp", VanDerPol::new(0.4))
        .register("threebody", ThreeBody::new([1e-3, 8e-4, 1.2e-3]))
        .register("convflow", ConvFlow::random(4, 4, 5, 0.4))
        .config(ServeConfig {
            max_batch_size: 8,
            max_queue_delay: Duration::from_micros(200),
            queue_capacity: 64,
            workers: 2,
            ckpt_budget_bytes: 0,
            mem_budget_bytes: 0,
            quota_quantum: 32,
            quota_max_deficit: 128,
        })
        .start();

    let mut rng = Pcg64::seed(1515);
    for (name, f) in all_dynamics() {
        let d = f.dim();
        for case in 0..4 {
            let fixed = case % 2 == 0;
            let grad = case >= 2;
            let t1 = rng.range(0.2, 0.6);
            let z0: Vec<f32> = (0..d).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let lam: Vec<f32> = (0..d).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let mk = || {
                let r = if fixed {
                    SolveRequest::fixed(name, 0.0, t1, z0.clone(), 0.02).unwrap()
                } else {
                    SolveRequest::adaptive(name, 0.0, t1, z0.clone(), 1e-6, 1e-8).unwrap()
                };
                if grad {
                    r.with_grad(lam.clone())
                } else {
                    r
                }
            };
            let plain = server.submit(mk()).unwrap().wait().unwrap();
            let id = obs::mint(Duration::from_nanos(1 + case as u64));
            let mut req = mk();
            req.trace = Some(TraceCtx::root(id));
            let traced = server.submit(req).unwrap().wait().unwrap();
            let spans = obs::global().take(id);
            assert!(!spans.is_empty(), "{name} case {case}: traced run recorded nothing");

            let ctx = format!("{name} case {case}");
            match (&plain.payload, &traced.payload) {
                (Payload::Forward { z_t1: a }, Payload::Forward { z_t1: b }) => {
                    assert_eq!(a, b, "{ctx}: final state");
                }
                (
                    Payload::Gradient { z_t1: a, grad: ga },
                    Payload::Gradient { z_t1: b, grad: gb },
                ) => {
                    assert_eq!(a, b, "{ctx}: final state");
                    assert_eq!(ga.dl_dz0, gb.dl_dz0, "{ctx}: dl_dz0");
                    assert_eq!(ga.dl_dtheta, gb.dl_dtheta, "{ctx}: dl_dtheta");
                    assert_eq!(ga.meter.nfe_forward, gb.meter.nfe_forward, "{ctx}: nfe_f");
                    assert_eq!(ga.meter.nfe_backward, gb.meter.nfe_backward, "{ctx}: nfe_b");
                    assert_eq!(ga.meter.nfe_replay, gb.meter.nfe_replay, "{ctx}: nfe_r");
                    assert_eq!(ga.meter.vjp_calls, gb.meter.vjp_calls, "{ctx}: vjps");
                    assert_eq!(ga.meter.n_steps, gb.meter.n_steps, "{ctx}: steps");
                    assert_eq!(ga.meter.n_rejected, gb.meter.n_rejected, "{ctx}: rejected");
                }
                _ => panic!("{ctx}: payload classes diverged"),
            }
            assert_eq!(plain.stats.nfe, traced.stats.nfe, "{ctx}: stats nfe");
            assert_eq!(plain.stats.steps, traced.stats.steps, "{ctx}: stats steps");
            assert_eq!(plain.stats.n_rejected, traced.stats.n_rejected, "{ctx}: stats rej");
        }
    }
}

/// Property: `integrate_batch` + `aca_backward_batch` reproduce per-sample
/// `integrate` + `aca_backward` — bit-exact on the fixed-step path and to
/// ≤ 1e-6 relative on the adaptive path — for B ∈ {1, 3, 8} across random
/// dynamics, spans, step sizes and tolerances.
#[test]
fn prop_batch_matches_per_sample_solves() {
    let mut rng = Pcg64::seed(808);
    let rel_close =
        |a: f32, b: f32| -> bool { (a - b).abs() as f64 <= 1e-6 * (b.abs() as f64).max(1.0) };
    for case in 0..12 {
        let fixed = case % 2 == 0;
        for &bsz in &[1usize, 3, 8] {
            let tab = if fixed { tabs()[rng.below(6)] } else { tabs()[3 + rng.below(3)] };
            let f = VanDerPol::new(rng.range(0.2, 1.2) as f32);
            let t1 = rng.range(0.5, 2.0);
            let z0: Vec<f32> = (0..bsz * 2).map(|_| rng.range(-1.5, 1.5) as f32).collect();
            let lam: Vec<f32> = (0..bsz * 2).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let opts = if fixed {
                IntegrateOpts::fixed(rng.range(0.01, 0.05))
            } else {
                IntegrateOpts::with_tol(10f64.powf(rng.range(-7.0, -4.0)), 1e-9)
            };

            let bt = integrate_batch(&f, 0.0, t1, &z0, tab, &opts).unwrap();
            let gb = aca_backward_batch(&f, tab, &bt, &lam);

            for i in 0..bsz {
                let traj = integrate(&f, 0.0, t1, &z0[i * 2..(i + 1) * 2], tab, &opts).unwrap();
                let ga = aca_backward(&f, tab, &traj, &lam[i * 2..(i + 1) * 2]);
                let ctx = format!("case {case} ({}) B={bsz} sample {i}", tab.name);

                // Grid + bookkeeping equivalence (both paths).
                assert_eq!(bt.steps(i), traj.len(), "{ctx}: steps");
                assert_eq!(bt.tracks[i].nfe, traj.nfe, "{ctx}: nfe");
                assert_eq!(bt.tracks[i].n_rejected, traj.n_rejected, "{ctx}: rejected");
                assert_eq!(bt.checkpoint_bytes(i), traj.checkpoint_bytes(), "{ctx}: bytes");

                if fixed {
                    // Fixed-step path: bit-exact, checkpoints included.
                    assert_eq!(bt.tracks[i].ts, traj.ts, "{ctx}: grid");
                    assert_eq!(bt.tracks[i].hs, traj.hs, "{ctx}: step sizes");
                    for k in 0..=traj.len() {
                        assert_eq!(bt.z(i, k), traj.z(k).unwrap(), "{ctx}: checkpoint {k}");
                    }
                    assert_eq!(gb[i].dl_dz0, ga.dl_dz0, "{ctx}: gradient");
                } else {
                    for (a, b) in bt.last(i).iter().zip(traj.last().unwrap()) {
                        assert!(rel_close(*a, *b), "{ctx}: endpoint {a} vs {b}");
                    }
                    for (a, b) in gb[i].dl_dz0.iter().zip(&ga.dl_dz0) {
                        assert!(rel_close(*a, *b), "{ctx}: gradient {a} vs {b}");
                    }
                }
            }
        }
    }
}
