//! End-to-end tests of the `dist` subsystem on loopback sockets: threads
//! stand in for processes (CI additionally runs a real two-process smoke
//! via `examples/dist_train.rs`).
//!
//! The load-bearing claims: a W-rank training step is **bit-identical**
//! to the single-process `grad_accum_reference` fold and invariant to
//! message arrival order; worker death shrinks the membership and the
//! step still completes against the smaller world's reference; the
//! sharded serve dispatcher returns answers bit-identical to direct
//! solves, survives a shard crash, and propagates `Overloaded`
//! backpressure across the wire; and one traced HTTP request routed
//! through the dispatcher yields a single stitched cross-process JSONL
//! trace whose NFE attribution sums to the response's `CostMeter`.

use nodal::dist::reduce::leaves_from_json;
use nodal::dist::train::{hello_message, partial_messages};
use nodal::dist::{
    connect_retry, grad_accum_reference, key_hash, local_partial, recv_frame, run_root,
    run_worker, send_frame, shard_range, Dispatcher, DispatcherConfig, DistGrad, RootOpts,
    ShardServer, StepSpec, TransportOpts, DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES,
};
use nodal::obs;
use nodal::ode::analytic::{Linear, ThreeBody};
use nodal::ode::{integrate, tableau, IntegrateOpts, OdeFunc};
use nodal::serve::{
    HttpConfig, HttpServer, ServeConfig, ServeError, SolveRequest, SolveResponse, SolveServer,
    Tolerance,
};
use nodal::util::json::Json;
use nodal::util::Pcg64;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn make_spec<'a>(f: &'a (dyn OdeFunc + Sync), opts: IntegrateOpts, b: usize) -> StepSpec<'a> {
    let d = f.dim();
    let mut rng = Pcg64::seed(0xd157);
    // Short spans keep the three-body workload out of close encounters.
    StepSpec {
        f,
        tab: if opts.fixed_h.is_some() { tableau::rk4() } else { tableau::dopri5() },
        opts,
        t0s: vec![0.0; b],
        t1s: (0..b).map(|_| rng.range(0.05, 0.15)).collect(),
        z0: (0..b * d).map(|_| rng.uniform_f32() - 0.5).collect(),
        lam: (0..b * d).map(|_| rng.normal_f32()).collect(),
    }
}

/// Run one step with `world` ranks as threads; returns rank 0's result
/// and every worker's.
fn run_world(world: usize, spec: &StepSpec) -> (DistGrad, Vec<DistGrad>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|sc| {
        let workers: Vec<_> = (1..world)
            .map(|r| {
                let addr = addr.clone();
                sc.spawn(move || run_worker(&addr, r, spec, &TransportOpts::default()))
            })
            .collect();
        let root = run_root(&listener, world, spec, &RootOpts::default()).unwrap();
        let ws = workers.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
        (root, ws)
    })
}

/// The acceptance bar: across two dynamics, fixed and adaptive stepping,
/// and world sizes 1, 2 and 4, the distributed gradient is bit-identical
/// to the single-process reference fold for the same membership size,
/// and every rank holds the same bits.
#[test]
fn distributed_step_matches_reference_bits_across_worlds() {
    let linear = Linear::new(-0.6, 3);
    let threebody = ThreeBody::new([1e-3, 8e-4, 1.2e-3]);
    let dynamics: [(&str, &(dyn OdeFunc + Sync)); 2] =
        [("linear", &linear), ("threebody", &threebody)];
    let regimes: [(&str, IntegrateOpts); 2] = [
        ("fixed", IntegrateOpts::fixed(0.01)),
        ("adaptive", IntegrateOpts::with_tol(1e-5, 1e-7)),
    ];
    for (dname, f) in dynamics {
        for (rname, opts) in &regimes {
            let spec = make_spec(f, opts.clone(), 6);
            for world in [1usize, 2, 4] {
                let want = bits(&grad_accum_reference(&spec, world).unwrap());
                let (root, workers) = if world == 1 {
                    let p = local_partial(&spec, 0..spec.n_samples()).unwrap();
                    let g =
                        DistGrad { leaves: p.leaves, members: vec![0], attempts: 1, nfe: p.nfe };
                    (g, Vec::new())
                } else {
                    run_world(world, &spec)
                };
                let label = format!("{dname}/{rname}/w{world}");
                assert_eq!(root.attempts, 1, "{label}: no failures expected");
                assert_eq!(root.members, (0..world).collect::<Vec<_>>(), "{label}");
                assert_eq!(bits(root.dl_dtheta()), want, "{label}: root vs reference");
                for (i, w) in workers.iter().enumerate() {
                    assert_eq!(bits(w.dl_dtheta()), want, "{label}: worker {} vs reference", i + 1);
                    assert_eq!(w.members, root.members, "{label}");
                }
            }
        }
    }
}

/// A worker that speaks the protocol through the public wire primitives,
/// with an injected delay before its partial — so two runs produce very
/// different arrival orders at rank 0.
fn delayed_worker(addr: &str, rank: usize, spec: &StepSpec, delay: Duration) -> Vec<u32> {
    let mut s = connect_retry(addr, &TransportOpts::default()).unwrap();
    send_frame(&mut s, &hello_message(rank)).unwrap();
    loop {
        let m = recv_frame(&mut s).unwrap();
        match m.get("kind").unwrap().as_str().unwrap() {
            "step" => {
                std::thread::sleep(delay);
                let attempt = m.get("attempt").unwrap().as_usize().unwrap();
                let members: Vec<usize> = m
                    .get("members")
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_usize().unwrap())
                    .collect();
                let pos = members.iter().position(|&r| r == rank).unwrap();
                let p = local_partial(spec, shard_range(spec.n_samples(), members.len(), pos))
                    .unwrap();
                let msgs =
                    partial_messages(rank, attempt, &p, DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES);
                for msg in &msgs {
                    send_frame(&mut s, msg).unwrap();
                }
            }
            "reduced" => {
                let leaves = leaves_from_json(m.get("leaves").unwrap()).unwrap();
                return bits(&leaves[0].values);
            }
            k => panic!("unexpected kind {k}"),
        }
    }
}

/// Arrival order must not shape the result: two runs whose workers sleep
/// wildly different amounts before answering produce the same bits.
#[test]
fn reduction_is_invariant_to_arrival_order() {
    let f = Linear::new(-0.8, 2);
    let spec = make_spec(&f, IntegrateOpts::with_tol(1e-5, 1e-7), 9);
    let spec = &spec;
    let want = bits(&grad_accum_reference(spec, 4).unwrap());
    for delays_ms in [[0u64, 40, 15], [35, 0, 50]] {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (root, worker_views) = std::thread::scope(|sc| {
            let workers: Vec<_> = delays_ms
                .iter()
                .enumerate()
                .map(|(i, &ms)| {
                    let addr = addr.clone();
                    sc.spawn(move || {
                        delayed_worker(&addr, i + 1, spec, Duration::from_millis(ms))
                    })
                })
                .collect();
            let root = run_root(&listener, 4, spec, &RootOpts::default()).unwrap();
            let views: Vec<Vec<u32>> = workers.into_iter().map(|h| h.join().unwrap()).collect();
            (root, views)
        });
        assert_eq!(bits(root.dl_dtheta()), want, "delays {delays_ms:?}");
        for v in worker_views {
            assert_eq!(v, want, "broadcast result, delays {delays_ms:?}");
        }
    }
}

/// Worker death mid-step: the membership shrinks, the batch re-partitions
/// over the survivors, and the result equals the smaller world's
/// reference — stale partials from the aborted attempt are discarded.
#[test]
fn worker_death_shrinks_the_membership_deterministically() {
    let f = Linear::new(-0.5, 3);
    let spec = make_spec(&f, IntegrateOpts::with_tol(1e-5, 1e-7), 8);
    let spec = &spec;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let root = std::thread::scope(|sc| {
        let survivor = {
            let addr = addr.clone();
            sc.spawn(move || run_worker(&addr, 1, spec, &TransportOpts::default()))
        };
        // Rank 2 registers, reads the first step broadcast, then dies.
        let deserter = {
            let addr = addr.clone();
            sc.spawn(move || {
                let mut s = connect_retry(&addr, &TransportOpts::default()).unwrap();
                send_frame(&mut s, &hello_message(2)).unwrap();
                let _ = recv_frame(&mut s);
            })
        };
        let root = run_root(&listener, 3, spec, &RootOpts::default()).unwrap();
        deserter.join().unwrap();
        let w = survivor.join().unwrap().unwrap();
        assert_eq!(bits(w.dl_dtheta()), bits(root.dl_dtheta()));
        root
    });
    assert_eq!(root.members, vec![0, 1], "rank 2 must be evicted");
    assert!(root.attempts >= 2, "the step must have retried");
    let want = bits(&grad_accum_reference(spec, 2).unwrap());
    assert_eq!(bits(root.dl_dtheta()), want, "survivors must match the 2-rank reference");
}

// ---------------------------------------------------------------------------
// Sharded serving.

fn shard_server(cfg: Option<ServeConfig>) -> SolveServer {
    let b = SolveServer::builder().register("linear", Linear::new(-0.7, 3));
    match cfg {
        Some(c) => b.config(c).start(),
        None => b.start(),
    }
}

fn serve_req(rtol: f64, rng: &mut Pcg64) -> SolveRequest {
    let z0: Vec<f32> = (0..3).map(|_| rng.uniform_f32() + 0.1).collect();
    SolveRequest::adaptive("linear", 0.0, 1.0, z0, rtol, 1e-8).unwrap()
}

/// Ground truth for a served request: the direct scalar solve.
fn direct_solve(req: &SolveRequest) -> Vec<f32> {
    let opts = match req.tol {
        Tolerance::Adaptive { rtol, atol } => IntegrateOpts::with_tol(rtol, atol),
        Tolerance::Fixed { h } => IntegrateOpts::fixed(h),
    };
    let f = Linear::new(-0.7, 3);
    let traj = integrate(&f, req.t0, req.t1, &req.z0, req.tab, &opts).unwrap();
    traj.last().unwrap().to_vec()
}

/// Two rtols whose batch keys hash to different shards of a 2-fleet, so
/// the routing test deterministically exercises both shards.
fn two_parities() -> (f64, f64) {
    let mut rng = Pcg64::seed(1);
    let (mut even, mut odd) = (None, None);
    for i in 1..200u32 {
        let rtol = f64::from(i) * 1e-7;
        let h = key_hash(&serve_req(rtol, &mut rng).batch_key());
        if h % 2 == 0 && even.is_none() {
            even = Some(rtol);
        } else if h % 2 == 1 && odd.is_none() {
            odd = Some(rtol);
        }
        if let (Some(e), Some(o)) = (even, odd) {
            return (e, o);
        }
    }
    panic!("no parity split in 200 candidate keys");
}

/// Mixed-key traffic across two shards: every answer bit-identical to a
/// direct solve, both shards see traffic, the fleet report adds up — and
/// after one shard is crashed mid-run, the survivor still answers
/// everything bit-exactly.
#[test]
fn dispatcher_preserves_answers_and_survives_shard_death() {
    let shard_a = ShardServer::spawn(shard_server(None), "127.0.0.1:0").unwrap();
    let shard_b = ShardServer::spawn(shard_server(None), "127.0.0.1:0").unwrap();
    let addrs = vec![shard_a.addr().to_string(), shard_b.addr().to_string()];
    // steal_margin 0: pure key affinity, so per-shard traffic is exactly
    // the hash split and both shards are guaranteed work.
    let cfg = DispatcherConfig { steal_margin: 0, ..DispatcherConfig::default() };
    let dispatcher = Dispatcher::connect(&addrs, &cfg).unwrap();

    let (rtol_even, rtol_odd) = two_parities();
    let mut rng = Pcg64::seed(0xbead);
    let reqs: Vec<SolveRequest> = (0..16)
        .map(|i| serve_req(if i % 2 == 0 { rtol_even } else { rtol_odd }, &mut rng))
        .collect();
    let handles: Vec<_> = reqs.iter().map(|r| dispatcher.submit(r.clone()).unwrap()).collect();
    for (req, h) in reqs.iter().zip(handles) {
        let resp = h.wait().unwrap();
        assert_eq!(bits(resp.z_t1()), bits(&direct_solve(req)), "served answer drifted");
    }
    let report = dispatcher.metrics().unwrap();
    assert_eq!(report.shards.len(), 2);
    for (addr, m) in &report.shards {
        assert!(m.submitted > 0, "shard {addr} saw no traffic");
    }
    let totals = report.totals();
    assert_eq!(totals.submitted, 16);
    assert_eq!(totals.completed, 16);
    assert_eq!(totals.rejected, 0);

    // Crash shard A (no drain — sockets just die) and keep going: the
    // dispatcher re-dispatches its pending work and re-routes its keys.
    shard_a.abort();
    let reqs: Vec<SolveRequest> = (0..12)
        .map(|i| serve_req(if i % 2 == 0 { rtol_even } else { rtol_odd }, &mut rng))
        .collect();
    let handles: Vec<_> = reqs.iter().map(|r| dispatcher.submit(r.clone()).unwrap()).collect();
    for (req, h) in reqs.iter().zip(handles) {
        let resp = h.wait().unwrap();
        assert_eq!(bits(resp.z_t1()), bits(&direct_solve(req)), "failover answer drifted");
    }
    assert_eq!(dispatcher.healthy_shards(), 1, "exactly one shard must remain");
    dispatcher.shutdown();
}

/// `Overloaded` crosses the wire: a shard with a one-request admission
/// cap sheds the overflow end-to-end, and the admitted request still
/// completes.
#[test]
fn overload_backpressure_propagates_end_to_end() {
    let cfg = ServeConfig {
        max_batch_size: 8,
        max_queue_delay: Duration::from_secs(3600), // flush only on drain
        queue_capacity: 1,
        workers: 1,
        ckpt_budget_bytes: 0,
        mem_budget_bytes: 0,
        quota_quantum: 32,
        quota_max_deficit: 128,
    };
    let shard = ShardServer::spawn(shard_server(Some(cfg)), "127.0.0.1:0").unwrap();
    let dispatcher =
        Dispatcher::connect(&[shard.addr().to_string()], &DispatcherConfig::default()).unwrap();
    let mut rng = Pcg64::seed(5);
    let reqs: Vec<SolveRequest> = (0..3).map(|_| serve_req(1e-5, &mut rng)).collect();
    let handles: Vec<_> = reqs.iter().map(|r| dispatcher.submit(r.clone()).unwrap()).collect();
    // The shard serves its connection in order: the first request is
    // admitted (and parked by the far-future deadline), the other two
    // bounce off the one-slot admission cap.
    let mut results: Vec<Result<_, _>> = Vec::new();
    std::thread::scope(|sc| {
        let waiter = sc.spawn(|| {
            handles.into_iter().map(|h| h.wait()).collect::<Vec<_>>()
        });
        // Wait until both rejections are recorded, then release the
        // admitted request.
        let deadline = 400; // x 5ms = 2s
        for _ in 0..deadline {
            if shard.server().metrics().rejected >= 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(shard.server().metrics().rejected, 2, "two requests must be shed");
        shard.server().drain();
        results = waiter.join().unwrap();
    });
    assert_eq!(results.len(), 3);
    assert!(results[0].is_ok(), "the admitted request must complete");
    for r in &results[1..] {
        assert_eq!(r.as_ref().unwrap_err(), &ServeError::Overloaded);
    }
    let resp = results[0].as_ref().unwrap();
    assert_eq!(bits(resp.z_t1()), bits(&direct_solve(&reqs[0])), "admitted answer drifted");
}

// ---------------------------------------------------------------------------
// Cross-process trace stitching.

/// Minimal raw HTTP client (same discipline as `http_integration.rs`: the
/// test frames its own traffic instead of trusting the code under test).
fn send_http(s: &mut TcpStream, method: &str, path: &str, hdrs: &[(&str, &str)], body: &str) {
    let mut req = format!("{method} {path} HTTP/1.1\r\ncontent-length: {}\r\n", body.len());
    for (k, v) in hdrs {
        req.push_str(&format!("{k}: {v}\r\n"));
    }
    req.push_str("\r\n");
    req.push_str(body);
    s.write_all(req.as_bytes()).unwrap();
}

/// Read one response: status, lower-cased headers, body.
fn read_http(r: &mut BufReader<TcpStream>) -> (u16, Vec<(String, String)>, String) {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let h = h.trim_end().to_string();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').unwrap();
        let (k, v) = (k.trim().to_ascii_lowercase(), v.trim().to_string());
        if k == "content-length" {
            len = v.parse().unwrap();
        }
        headers.push((k, v));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).unwrap();
    (status, headers, String::from_utf8(body).unwrap())
}

/// The PR's acceptance bar: one traced gradient request through the HTTP
/// front door, routed by the dispatcher across a two-shard fleet running a
/// thinning checkpoint budget, yields a **single stitched JSONL trace** —
/// front-door spans, the routing event tagged with the chosen shard, and
/// the shard-side queue-wait / batch-formation / solve / forward / reverse
/// / replay phases all under one trace id, with per-span NFE attribution
/// summing exactly to the `CostMeter` the response itself carries.
#[test]
fn traced_dispatcher_solve_yields_one_stitched_jsonl_trace() {
    let cfg = ServeConfig {
        max_batch_size: 8,
        // Tiny deadline: the singleton batch flushes on the next batcher
        // tick (the HTTP request blocks its connection until answered).
        max_queue_delay: Duration::from_micros(50),
        queue_capacity: 64,
        workers: 1,
        ckpt_budget_bytes: 64, // tiny budget → thinned store → segment replay
        mem_budget_bytes: 0,
        quota_quantum: 32,
        quota_max_deficit: 128,
    };
    let shard_a = ShardServer::spawn(shard_server(Some(cfg.clone())), "127.0.0.1:0").unwrap();
    let shard_b = ShardServer::spawn(shard_server(Some(cfg)), "127.0.0.1:0").unwrap();
    let addrs = vec![shard_a.addr().to_string(), shard_b.addr().to_string()];
    let dispatcher = Arc::new(Dispatcher::connect(&addrs, &DispatcherConfig::default()).unwrap());

    let dir = std::env::temp_dir().join(format!("nodal-trace-dist-{}", std::process::id()));
    let http_cfg = HttpConfig {
        trace: obs::TraceKnobs { sample_n: 0, dir: dir.clone() },
        ..HttpConfig::default()
    };
    let mut http =
        HttpServer::spawn_front_at(dispatcher.clone(), "127.0.0.1:0", http_cfg).unwrap();

    // 20 fixed rk4 steps of a dim-3 state: far past the 64-byte budget, so
    // the backward pass must replay thinned segments.
    let id = "00000000000000d1";
    let req = SolveRequest::fixed("linear", 0.0, 1.0, vec![0.4, -0.2, 0.9], 0.05)
        .unwrap()
        .with_grad(vec![1.0, 1.0, 1.0]);
    let mut w = TcpStream::connect(http.addr()).unwrap();
    let mut r = BufReader::new(w.try_clone().unwrap());
    send_http(&mut w, "POST", "/v1/solve", &[("x-nodal-trace", id)], &req.to_json().to_string());
    let (status, headers, body) = read_http(&mut r);
    assert_eq!(status, 200, "{body}");
    let echoed = headers.iter().find(|(k, _)| k == "x-nodal-trace").map(|(_, v)| v.as_str());
    assert_eq!(echoed, Some(id), "trace id echoes on the response");
    let resp = SolveResponse::from_json(&Json::parse(&body).unwrap()).unwrap();
    let meter = resp.grad().expect("gradient payload").meter.clone();
    assert!(meter.nfe_replay > 0, "the tiny budget must force segment replay");

    // The JSONL export was written before the response bytes, so it is
    // complete by now: one file, one trace, every phase stitched in.
    let text = std::fs::read_to_string(dir.join(format!("{id}.jsonl"))).unwrap();
    let spans: Vec<obs::SpanRec> = text
        .lines()
        .map(|l| obs::span_from_json(&Json::parse(l).unwrap()).unwrap())
        .collect();
    let find = |name: &str| {
        let hits: Vec<&obs::SpanRec> = spans.iter().filter(|s| s.name == name).collect();
        assert_eq!(hits.len(), 1, "expected exactly one {name} span");
        *hits[0]
    };
    let http_span = find(obs::HTTP_REQUEST);
    let adm = find(obs::ADMISSION);
    let dispatch = find(obs::DISPATCH);
    let qw = find(obs::QUEUE_WAIT);
    let bf = find(obs::BATCH_FORM);
    let solve = find(obs::SOLVE);
    let fwd = find(obs::FORWARD);
    let rev = find(obs::REVERSE);
    let replay = find(obs::REPLAY);

    // One stitched tree: front door → routing event → shard-side phases.
    assert_eq!(http_span.parent, 0, "http_request is the root");
    assert_eq!(http_span.get_attr("status"), Some(200));
    assert_eq!(adm.parent, http_span.span);
    assert_eq!(dispatch.parent, adm.span, "routing hangs off admission");
    for phase in [&qw, &bf, &solve] {
        assert_eq!(phase.parent, dispatch.span, "{} under dispatch", phase.name);
    }
    assert_eq!(fwd.parent, solve.span);
    assert_eq!(rev.parent, solve.span);
    assert_eq!(replay.parent, rev.span, "replay is attributed under reverse");

    // Every shard-side span is tagged with the one shard the router chose.
    let chosen = dispatch.shard;
    assert!(chosen == 0 || chosen == 1, "chosen shard index, got {chosen}");
    for phase in [&qw, &bf, &solve, &fwd, &rev, &replay] {
        assert_eq!(phase.shard, chosen, "{} tagged with the serving shard", phase.name);
    }
    assert_eq!(http_span.shard, -1, "front-door spans are shard-agnostic");

    // NFE attribution: per-phase span attrs reproduce the CostMeter the
    // response carries, and their sum is the request's total f-eval bill.
    assert_eq!(fwd.get_attr("nfe"), Some(meter.nfe_forward as u64));
    assert_eq!(rev.get_attr("nfe"), Some(meter.nfe_backward as u64));
    assert_eq!(replay.get_attr("nfe"), Some(meter.nfe_replay as u64));
    let span_nfe = fwd.get_attr("nfe").unwrap()
        + rev.get_attr("nfe").unwrap()
        + replay.get_attr("nfe").unwrap();
    assert_eq!(
        span_nfe,
        (meter.nfe_forward + meter.nfe_backward + meter.nfe_replay) as u64,
        "span NFE attribution sums to the CostMeter totals"
    );
    assert!(fwd.get_attr("rounds").unwrap() > 0, "forward active-set rounds counted");
    assert!(fwd.get_attr("sweeps").unwrap() > 0, "forward stage sweeps counted");

    // The trace route serves the same stitched tree it exported.
    send_http(&mut w, "GET", &format!("/v1/trace/{id}"), &[], "");
    let (status, _, body) = read_http(&mut r);
    assert_eq!(status, 200, "{body}");
    let doc = Json::parse(&body).unwrap();
    let served = obs::spans_from_json(doc.get("spans").unwrap());
    assert_eq!(served.len(), spans.len(), "route and JSONL agree on the span count");

    http.shutdown();
    dispatcher.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
