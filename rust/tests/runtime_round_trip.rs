//! Integration: the python-AOT → rust-PJRT bridge on real artifacts.
//!
//! Requires `make artifacts` to have produced `artifacts/` at the repo root
//! (tests are skipped with a message otherwise, so `cargo test` stays green
//! on a fresh checkout — CI runs `make test` which builds artifacts first).

use nodal::grad::{self, Method};
use nodal::ode::{integrate, tableau, IntegrateOpts, OdeFunc};
use nodal::runtime::{hlo_model::Target, Engine, HloModel, RecurrentBaseline};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/spiral/manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn load_spiral() -> (Engine, HloModel) {
    let mut engine = Engine::cpu().unwrap();
    let mut model = HloModel::load(&mut engine, std::path::Path::new("artifacts/spiral")).unwrap();
    model.init_params(42).unwrap();
    (engine, model)
}

#[test]
fn spiral_f_eval_shapes_and_finiteness() {
    require_artifacts!();
    let (_e, model) = load_spiral();
    let n = model.dim();
    let z: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.01).sin()).collect();
    let mut dz = vec![0.0f32; n];
    model.eval(0.0, &z, &mut dz);
    assert!(dz.iter().all(|v| v.is_finite()));
    assert!(dz.iter().any(|&v| v != 0.0), "dynamics must be nontrivial");
}

#[test]
fn spiral_vjp_consistent_with_finite_difference() {
    require_artifacts!();
    let (_e, model) = load_spiral();
    let n = model.dim();
    let z: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.037).cos() * 0.5).collect();
    let w: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.051).sin()).collect();
    let v: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.013).cos()).collect();

    let mut wjz = vec![0.0f32; n];
    let mut wjp = vec![0.0f32; model.n_params()];
    model.vjp(0.0, &z, &w, &mut wjz, &mut wjp);

    // <w^T J, v> vs FD of <w, f(z + eps v)>
    let eps = 1e-3f32;
    let zp: Vec<f32> = z.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
    let zm: Vec<f32> = z.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
    let mut fp = vec![0.0f32; n];
    let mut fm = vec![0.0f32; n];
    model.eval(0.0, &zp, &mut fp);
    model.eval(0.0, &zm, &mut fm);
    let fd: f64 = (0..n)
        .map(|i| w[i] as f64 * ((fp[i] - fm[i]) / (2.0 * eps)) as f64)
        .sum();
    let got = nodal::tensor::dot(&wjz, &v);
    assert!(
        (got - fd).abs() < 0.05 * fd.abs().max(0.1),
        "vjp {got} vs fd {fd}"
    );
}

#[test]
fn spiral_jvp_adjoint_identity() {
    require_artifacts!();
    let (_e, model) = load_spiral();
    let n = model.dim();
    let z: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.023).sin()).collect();
    let w: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.041).cos()).collect();
    let v: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.017).sin()).collect();
    let mut jv = vec![0.0f32; n];
    model.jvp(0.0, &z, &v, &mut jv);
    let mut wj = vec![0.0f32; n];
    let mut wjp = vec![0.0f32; model.n_params()];
    model.vjp(0.0, &z, &w, &mut wj, &mut wjp);
    let lhs = nodal::tensor::dot(&w, &jv);
    let rhs = nodal::tensor::dot(&wj, &v);
    assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
}

#[test]
fn spiral_full_training_step_all_methods_reduce_loss() {
    require_artifacts!();
    let (_e, mut model) = load_spiral();
    let b = model.manifest.batch;
    let din = model.manifest.dim_in;

    // Tiny synthetic batch: class = x0 > 0.
    let mut x = vec![0.0f32; b * din];
    let mut y = vec![0i32; b];
    for i in 0..b {
        let v = if i % 2 == 0 { 0.8 } else { -0.8 };
        x[i * din] = v;
        x[i * din + 1] = -v * 0.3;
        y[i] = (v > 0.0) as i32;
    }
    let target = Target::Classes(y);
    let tab = tableau::heun_euler();
    let opts = IntegrateOpts {
        record_trials: true,
        ..IntegrateOpts::with_tol(1e-2, 1e-2)
    };

    for method in Method::all() {
        model.init_params(7).unwrap();
        let mut last_loss = f64::INFINITY;
        for step in 0..8 {
            let z0 = model.encode(&x).unwrap();
            let traj = integrate(&model, 0.0, 1.0, &z0, tab, &opts).unwrap();
            let mut dtheta = vec![0.0f32; model.n_params()];
            let (lam, loss) = model
                .decode_loss_vjp(traj.last().unwrap(), &target, &mut dtheta)
                .unwrap();
            let g = grad::backward(&model, tab, &traj, &lam, method, &opts).unwrap();
            for (d, s) in dtheta.iter_mut().zip(&g.dl_dtheta) {
                *d += s;
            }
            model.encode_vjp_accum(&x, &g.dl_dz0, &mut dtheta).unwrap();
            // plain SGD
            let lr = 0.5f32;
            let p: Vec<f32> = model
                .params()
                .iter()
                .zip(&dtheta)
                .map(|(p, g)| p - lr * g)
                .collect();
            model.set_params(&p);
            if step == 0 {
                last_loss = loss;
            } else if step == 7 {
                assert!(
                    loss < last_loss,
                    "{}: loss did not decrease: {last_loss} -> {loss}",
                    method.name()
                );
            }
        }
    }
}

#[test]
fn init_params_deterministic_across_loads() {
    require_artifacts!();
    let mut engine = Engine::cpu().unwrap();
    let mut a = HloModel::load(&mut engine, std::path::Path::new("artifacts/spiral")).unwrap();
    let mut b = HloModel::load(&mut engine, std::path::Path::new("artifacts/spiral")).unwrap();
    a.init_params(5).unwrap();
    b.init_params(5).unwrap();
    assert_eq!(a.params(), b.params());
    b.init_params(6).unwrap();
    assert_ne!(a.params(), b.params());
}

#[test]
fn recurrent_baseline_round_trip() {
    require_artifacts!();
    let mut engine = Engine::cpu().unwrap();
    let mut m =
        RecurrentBaseline::load(&mut engine, std::path::Path::new("artifacts/ts_rnn")).unwrap();
    m.init_params(1).unwrap();
    let man = m.manifest.clone();
    let x = vec![0.1f32; man.batch * man.seq_len * man.dim_in];
    let y = vec![0.2f32; man.batch * man.seq_len * man.dim_out];
    let (loss, grad) = m.loss_grad(&x, &y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(grad.len(), man.n_params);
    // One SGD step reduces this loss.
    for (p, g) in m.params.iter_mut().zip(&grad) {
        *p -= 0.5 * g;
    }
    let (loss2, _) = m.loss_grad(&x, &y).unwrap();
    assert!(loss2 < loss, "{loss} -> {loss2}");
    let pred = m.predict(&x).unwrap();
    assert_eq!(pred.len(), man.batch * man.seq_len * man.dim_out);
}

#[test]
fn lstm_rollout_round_trip() {
    require_artifacts!();
    let mut engine = Engine::cpu().unwrap();
    let mut m =
        RecurrentBaseline::load(&mut engine, std::path::Path::new("artifacts/tb_lstm")).unwrap();
    m.init_params(2).unwrap();
    let man = m.manifest.clone();
    let x0 = vec![0.5f32; man.batch * man.dim_in];
    let traj = m.rollout(&x0).unwrap();
    assert_eq!(traj.len(), man.batch * man.rollout_steps * man.dim_out);
    assert!(traj.iter().all(|v| v.is_finite()));
}
