//! Integration tests over the full training stack with real artifacts
//! (skipped with a notice if `make artifacts` has not run).

use nodal::data::timeseries::TimeSeriesDataset;
use nodal::data::SpiralDataset;
use nodal::grad::Method;
use nodal::ode::{tableau, IntegrateOpts, OdeFunc};
use nodal::runtime::hlo_model::Target;
use nodal::runtime::{Engine, HloModel};
use nodal::train::segmented::{segmented_eval, segmented_loss_grad};
use nodal::train::{LrSchedule, TrainConfig, Trainer};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/spiral/manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn trainer_learns_spirals_with_aca() {
    require_artifacts!();
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("spiral")).unwrap();
    model.init_params(3).unwrap();
    let data = SpiralDataset::generate(512, 128, 0.03, 5);
    let cfg = TrainConfig {
        method: Method::Aca,
        epochs: 5,
        lr: LrSchedule::Constant(0.1),
        rtol: 1e-2,
        atol: 1e-2,
        ..Default::default()
    };
    let mut tr = Trainer::new(cfg);
    tr.fit(&mut model, tableau::heun_euler(), &data).unwrap();
    assert!(
        tr.final_acc() > 0.9,
        "spiral accuracy too low: {}",
        tr.final_acc()
    );
    // History is complete and wall-clock increases.
    assert_eq!(tr.history.len(), 5);
    for w in tr.history.windows(2) {
        assert!(w[1].wall_s >= w[0].wall_s);
    }
}

#[test]
fn trainer_histories_differ_by_method_cost() {
    require_artifacts!();
    let data = SpiralDataset::generate(128, 64, 0.03, 5);
    let mut nfe_b = std::collections::HashMap::new();
    for method in [Method::Aca, Method::Adjoint] {
        let mut engine = Engine::cpu().unwrap();
        let mut model =
            HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("spiral")).unwrap();
        model.init_params(3).unwrap();
        let cfg = TrainConfig {
            method,
            epochs: 1,
            lr: LrSchedule::Constant(0.05),
            ..Default::default()
        };
        let mut tr = Trainer::new(cfg);
        tr.fit(&mut model, tableau::dopri5(), &data).unwrap();
        nfe_b.insert(method.name(), tr.history[0].nfe_backward);
    }
    // Adjoint's reverse solve costs more f-work than ACA's checkpoint replay
    // (N_r reverse steps of a 2D+P system vs N_t stage recomputations).
    assert!(
        nfe_b["adjoint"] > 0.0 && nfe_b["aca"] > 0.0,
        "meters recorded: {nfe_b:?}"
    );
}

#[test]
fn segmented_training_reduces_timeseries_loss_all_methods() {
    require_artifacts!();
    let data = TimeSeriesDataset::generate(1, 1, 32, 5.0, 9);
    let g = &data.train[0];
    let tab = tableau::dopri5();
    for method in Method::all() {
        let mut engine = Engine::cpu().unwrap();
        let mut model =
            HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("ts")).unwrap();
        model.init_params(1).unwrap();
        let opts = IntegrateOpts {
            record_trials: method == Method::Naive,
            ..IntegrateOpts::with_tol(1e-3, 1e-4)
        };
        let targets: Vec<Target> =
            (0..g.n_targets()).map(|k| Target::Values(g.target_at(k))).collect();
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for step in 0..6 {
            let z0 = model.encode(&g.encoder_input()).unwrap();
            let sg =
                segmented_loss_grad(&model, tab, &opts, method, &z0, g.target_times(), &targets)
                    .unwrap();
            if step == 0 {
                first = sg.loss;
            }
            last = sg.loss;
            let mut dtheta = sg.dtheta;
            model
                .encode_vjp_accum(&g.encoder_input(), &sg.dl_dz0, &mut dtheta)
                .unwrap();
            let params: Vec<f32> = model
                .params()
                .iter()
                .zip(&dtheta)
                .map(|(p, g)| p - 0.05 * g)
                .collect();
            model.set_params(&params);
        }
        assert!(
            last < first,
            "{}: segmented loss did not decrease ({first} -> {last})",
            method.name()
        );
    }
}

#[test]
fn segmented_eval_consistent_with_loss_grad_forward() {
    require_artifacts!();
    let data = TimeSeriesDataset::generate(1, 0, 32, 5.0, 13);
    let g = &data.train[0];
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("ts")).unwrap();
    model.init_params(2).unwrap();
    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(1e-4, 1e-5);
    let targets: Vec<Target> =
        (0..g.n_targets()).map(|k| Target::Values(g.target_at(k))).collect();
    let z0 = model.encode(&g.encoder_input()).unwrap();
    let sg =
        segmented_loss_grad(&model, tab, &opts, Method::Aca, &z0, g.target_times(), &targets)
            .unwrap();
    let (mse, preds) =
        segmented_eval(&model, tab, &opts, &z0, g.target_times(), &targets).unwrap();
    assert!((sg.loss - mse).abs() < 1e-6 * mse.abs().max(1e-9));
    assert_eq!(preds.len(), g.n_targets());
}

#[test]
fn gradient_methods_agree_on_smooth_model() {
    require_artifacts!();
    // With tight tolerance all three methods should produce nearly the same
    // gradient on the spiral model — the differences are O(tol).
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("spiral")).unwrap();
    model.init_params(11).unwrap();
    let data = SpiralDataset::generate(64, 0, 0.03, 2);
    let ids: Vec<usize> = (0..model.manifest.batch).collect();
    let (x, y) = data.gather(&ids);
    let tab = tableau::dopri5();

    let grad_of = |method: Method| -> Vec<f32> {
        let cfg = TrainConfig {
            method,
            rtol: 1e-6,
            atol: 1e-8,
            ..Default::default()
        };
        let tr = Trainer::new(cfg);
        let (_, dtheta, _) = tr.loss_grad(&model, tab, &x, &y).unwrap();
        dtheta
    };
    let ga = grad_of(Method::Aca);
    let gj = grad_of(Method::Adjoint);
    let na = nodal::tensor::norm2(&ga);
    let dj: Vec<f32> = ga.iter().zip(&gj).map(|(a, b)| a - b).collect();
    assert!(nodal::tensor::norm2(&dj) < 0.05 * na, "adjoint vs aca");
    // The naive method legitimately deviates through the step-size chain
    // (paper Sec 3.3) — its agreement is only exact for fixed-step solves:
    let grad_fixed = |method: Method| -> Vec<f32> {
        let cfg = TrainConfig {
            method,
            rtol: 1e-6,
            atol: 1e-8,
            fixed_h: Some(0.1),
            ..Default::default()
        };
        let tr = Trainer::new(cfg);
        let (_, dtheta, _) = tr.loss_grad(&model, tab, &x, &y).unwrap();
        dtheta
    };
    assert_eq!(grad_fixed(Method::Aca), grad_fixed(Method::Naive), "fixed-step naive == aca");
}

#[test]
fn loss_grad_accum_matches_per_batch_sum() {
    require_artifacts!();
    // An accumulation group driven through the batched engine (one
    // integrate_batch + shared-stage backward_batch) must reproduce the sum
    // of per-batch scalar loss_grad results: per-sample solves and reverse
    // sweeps are bit-identical by the engine's equivalence guarantees, so
    // only the final gradient summation order may differ (O(ulp)).
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("spiral")).unwrap();
    model.init_params(7).unwrap();
    let b = model.manifest.batch;
    let data = SpiralDataset::generate(2 * b, 0, 0.03, 4);
    let tr = Trainer::new(TrainConfig { method: Method::Aca, ..Default::default() });

    let group: Vec<(Vec<f32>, Target)> = (0..2)
        .map(|k| {
            let ids: Vec<usize> = (k * b..(k + 1) * b).collect();
            data.gather(&ids)
        })
        .collect();
    let (loss_acc, dtheta_acc, meter_acc) =
        tr.loss_grad_accum(&model, tableau::dopri5(), &group).unwrap();

    let mut loss_ref = 0.0;
    let mut dtheta_ref = vec![0.0f32; model.n_params()];
    let mut nfe_ref = 0usize;
    for (x, y) in &group {
        let (loss, dtheta, meter) = tr.loss_grad(&model, tableau::dopri5(), x, y).unwrap();
        loss_ref += loss / group.len() as f64;
        for (d, s) in dtheta_ref.iter_mut().zip(&dtheta) {
            *d += s;
        }
        nfe_ref += meter.nfe_forward;
    }
    assert!((loss_acc - loss_ref).abs() < 1e-9 * loss_ref.abs().max(1.0));
    assert_eq!(meter_acc.nfe_forward, nfe_ref, "per-sample NFE accounting");
    let scale = nodal::tensor::norm2(&dtheta_ref).max(1e-9);
    let diff: Vec<f32> = dtheta_acc.iter().zip(&dtheta_ref).map(|(a, b)| a - b).collect();
    assert!(
        nodal::tensor::norm2(&diff) < 1e-5 * scale,
        "accumulated gradient diverged from per-batch sum"
    );
}

#[test]
fn dispatch_counter_tracks_pjrt_calls() {
    require_artifacts!();
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("spiral")).unwrap();
    model.init_params(0).unwrap();
    model.reset_dispatches();
    let n = model.dim();
    let z = vec![0.1f32; n];
    let mut dz = vec![0.0f32; n];
    model.eval(0.0, &z, &mut dz);
    model.eval(0.5, &z, &mut dz);
    assert_eq!(model.dispatches(), 2);
}
