//! Minimal offline drop-in for the `anyhow` error crate.
//!
//! The build environment vendors no external crates, so this crate
//! re-implements the subset of the anyhow API the workspace uses:
//!
//! * [`Error`] — a context-chained error value (message + cause chain);
//! * [`Result`] — `Result<T, Error>` with a defaulted error parameter, so
//!   `Result<T, String>` still names the std result type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that is what allows the blanket
//! `impl<E: std::error::Error> From<E> for Error` to coexist with the
//! standard library's identity `From` impl.

use std::fmt;

/// A context-chained error: the outermost message plus its causes,
/// outermost-first.
pub struct Error {
    msg: String,
    /// Cause messages, outermost cause first.
    causes: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), causes: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        let mut causes = Vec::with_capacity(self.causes.len() + 1);
        causes.push(self.msg);
        causes.extend(self.causes);
        Error { msg: c.to_string(), causes }
    }

    /// The error chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.causes.iter().map(String::as_str))
    }

    /// The innermost cause message.
    pub fn root_cause(&self) -> &str {
        self.causes.last().unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            for c in &self.causes {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.causes.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, c) in self.causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into [`Error`], capturing its source chain.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut causes = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            causes.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), causes }
    }
}

/// `anyhow::Result<T>`; the defaulted parameter keeps `Result<T, E>` usable
/// as the std result type under a `use anyhow::Result` import.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failing `Result`s and empty `Option`s.
pub trait Context<T> {
    /// Wrap the error value with a context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    /// Wrap the error value with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        let _: u32 = "nope".parse()?; // std error converts via `?`
        Ok(())
    }

    #[test]
    fn std_error_converts() {
        let e = fails().unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn context_chains_and_displays_outermost() {
        let e = fails().context("reading the config").unwrap_err();
        assert_eq!(e.to_string(), "reading the config");
        assert!(e.root_cause().contains("invalid digit"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "inner 7"]);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            ensure!(x != 1);
            if x == 2 {
                bail!("two is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert!(f(1).unwrap_err().to_string().contains("Condition failed"));
        assert_eq!(f(2).unwrap_err().to_string(), "two is right out");
    }
}
