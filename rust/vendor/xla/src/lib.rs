//! Offline stand-in for the `xla-rs` PJRT bindings.
//!
//! The container bakes no `xla_extension` shared library, so this crate
//! implements the API surface `nodal::runtime` compiles against:
//!
//! * [`Literal`] — host tensor marshalling (`vec1` / `reshape` / `to_vec` /
//!   `element_count`) is **fully functional**; the runtime's literal round
//!   trips and unit tests run against it unchanged.
//! * PJRT client / compilation / execution ([`PjRtClient`],
//!   [`PjRtLoadedExecutable`], [`HloModuleProto`], [`XlaComputation`]) are
//!   **gated**: constructors return a descriptive [`Error`] instead of
//!   aborting, so artifact-driven tests and experiments skip cleanly on
//!   machines without the native runtime.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml`; no call sites change.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring `xla_rs::Error` (implements `std::error::Error`, so
/// `anyhow` context adapters apply).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: the native xla_extension PJRT runtime is not linked into this offline \
             build — rebuild with the real xla-rs bindings to execute AOT artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            Data::F32(_) => "f32",
            Data::I32(_) => "i32",
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types a [`Literal`] can hold (mirrors xla-rs `NativeType`).
pub trait NativeType: sealed::Sealed + Copy {
    #[doc(hidden)]
    fn lit_from(v: &[Self]) -> Literal;
    #[doc(hidden)]
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn lit_from(v: &[f32]) -> Literal {
        Literal { data: Data::F32(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            other => Err(Error(format!("literal holds {}, not f32", other.dtype()))),
        }
    }
}

impl NativeType for i32 {
    fn lit_from(v: &[i32]) -> Literal {
        Literal { data: Data::I32(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            other => Err(Error(format!("literal holds {}, not i32", other.dtype()))),
        }
    }
}

/// A host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::lit_from(v)
    }

    /// Total number of elements.
    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Shape of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret under a new shape; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.element_count() {
            return Err(Error(format!(
                "cannot reshape {} elements into {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Decompose a tuple literal into its elements. The stand-in never
    /// produces tuple literals (they only come back from PJRT execution).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stand-in: parsing requires the native runtime).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parsing HLO text {path:?}")))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client (stand-in: construction requires the native runtime).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A device-resident buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_round_trip_i32() {
        let l = Literal::vec1(&[5i32, -6]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5, -6]);
        assert!(l.to_vec::<f32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn reshape_count_mismatch_errors() {
        let l = Literal::vec1(&[1.0f32; 5]);
        assert!(l.reshape(&[2, 3]).is_err());
    }

    #[test]
    fn runtime_is_gated_not_panicking() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla_extension"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
