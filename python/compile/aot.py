"""AOT lowering: JAX model zoo -> HLO text artifacts + manifests.

``make artifacts`` runs this once; the Rust coordinator then loads the HLO
text through the PJRT C API and Python never runs again.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--model spiral,img,...]
"""

import argparse
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so every
    artifact's outputs unwrap uniformly on the Rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(dt).name]


def _spec_json(s) -> Dict[str, Any]:
    return {"shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}


def lower_artifact(fn, arg_specs: List[jax.ShapeDtypeStruct], out_dir: str, name: str):
    """Lower ``fn`` at the given example specs; write HLO text; return the
    manifest entry."""
    # keep_unused: autonomous dynamics ignore `t`, parameterless heads ignore
    # theta — the artifact signature must stay stable regardless.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as fh:
        fh.write(text)
    # Output specs from the jitted abstract eval.
    out_aval = jax.eval_shape(fn, *arg_specs)
    outs = jax.tree_util.tree_leaves(out_aval)
    return {
        "file": fname,
        "inputs": [_spec_json(s) for s in arg_specs],
        "outputs": [_spec_json(s) for s in outs],
    }


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def export_node_model(m: M.NodeModel, root: str) -> None:
    out_dir = os.path.join(root, m.name)
    os.makedirs(out_dir, exist_ok=True)
    p, b, d = m.n_params, m.batch, m.dim_state
    theta, t, z, w = f32(p), f32(1), f32(b, d), f32(b, d)
    y = m.example_y()

    arts = {
        "init_params": lower_artifact(m.init_params_fn(), [i32(1)], out_dir, "init_params"),
        "f_eval": lower_artifact(m.f_eval_fn(), [theta, t, z], out_dir, "f_eval"),
        "f_vjp": lower_artifact(m.f_vjp_fn(), [theta, t, z, w], out_dir, "f_vjp"),
        "f_jvp": lower_artifact(m.f_jvp_fn(), [theta, t, z, w], out_dir, "f_jvp"),
        "decode_loss": lower_artifact(m.decode_loss_fn(), [theta, z, y], out_dir, "decode_loss"),
        "decode_loss_vjp": lower_artifact(
            m.decode_loss_vjp_fn(), [theta, z, y], out_dir, "decode_loss_vjp"
        ),
    }
    if m.encode is not None:
        x = f32(b, m.dim_in)
        arts["encode"] = lower_artifact(m.encode_fn(), [theta, x], out_dir, "encode")
        arts["encode_vjp"] = lower_artifact(
            m.encode_vjp_fn(), [theta, x, w], out_dir, "encode_vjp"
        )

    manifest = {
        "name": m.name,
        "kind": "node",
        "batch": b,
        "dim_in": m.dim_in,
        "dim_state": d,
        "dim_out": m.dim_out,
        "n_params": p,
        "loss": m.loss,
        "has_encoder": m.encode is not None,
        "artifacts": arts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"  {m.name}: P={p} B={b} D={d} -> {len(arts)} artifacts")


def export_recurrent_model(m: M.RecurrentModel, root: str) -> None:
    out_dir = os.path.join(root, m.name)
    os.makedirs(out_dir, exist_ok=True)
    theta = f32(m.n_params)
    x, y = m.example_x(), m.example_y()

    arts = {
        "init_params": lower_artifact(m.init_params_fn(), [i32(1)], out_dir, "init_params"),
        "loss_grad": lower_artifact(m.loss_grad_fn(), [theta, x, y], out_dir, "loss_grad"),
        "predict": lower_artifact(m.predict_fn(), [theta, x], out_dir, "predict"),
    }
    rollout = m.rollout_fn()
    if rollout is not None:
        arts["rollout"] = lower_artifact(
            rollout, [theta, f32(m.batch, m.dim_in)], out_dir, "rollout"
        )

    manifest = {
        "name": m.name,
        "kind": "recurrent",
        "batch": m.batch,
        "seq_len": m.seq_len,
        "dim_in": m.dim_in,
        "dim_out": m.dim_out,
        "hidden": m.hidden,
        "cell": m.cell,
        "n_params": m.n_params,
        "rollout_steps": m.rollout_steps,
        "artifacts": arts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"  {m.name}: P={m.n_params} B={m.batch} T={m.seq_len} -> {len(arts)} artifacts")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root directory")
    ap.add_argument("--model", default="", help="comma-separated model filter")
    args = ap.parse_args()
    wanted = {m for m in args.model.split(",") if m}
    os.makedirs(args.out, exist_ok=True)

    print("lowering NODE models:")
    for m in M.node_models():
        if not wanted or m.name in wanted:
            export_node_model(m, args.out)
    print("lowering recurrent baselines:")
    for m in M.recurrent_models():
        if not wanted or m.name in wanted:
            export_recurrent_model(m, args.out)
    # Freshness stamp for make.
    with open(os.path.join(args.out, ".stamp"), "w") as fh:
        fh.write("ok\n")
    print(f"artifacts written to {args.out}")


if __name__ == "__main__":
    main()
