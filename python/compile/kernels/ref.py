"""Pure-jnp oracles for the Pallas kernels.

pytest (python/tests/test_kernels.py) asserts ``assert_allclose`` between
each kernel and its oracle across a hypothesis-driven sweep of shapes and
values — this is the L1 correctness signal of the build.
"""

import jax.numpy as jnp

from .pairwise_aug import aug_jnp


def fused_linear_ref(x, w, b, activation: str = "none"):
    """Reference for kernels.fused_linear."""
    out = x @ w + b[None, :]
    if activation == "tanh":
        out = jnp.tanh(out)
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(activation)
    return out.astype(jnp.float32)


def pairwise_aug_ref(r):
    """Reference for kernels.pairwise_aug."""
    return aug_jnp(r)
