"""Fused ``act(x @ W + b)`` Pallas kernel — the hot inner op of every MLP
dynamics function `f` in the model zoo.

The paper's NODE evaluates `f` `N_t × s × m` times per forward pass, so the
per-layer matmul + bias + activation is the L1 hot spot. On TPU the kernel
tiles `x[B,K] @ W[K,N]` into `(bm, bn)` output blocks with the full K
dimension resident in VMEM (K ≤ 512 for all models ⇒ a (128,512) f32 x-tile
+ (512,128) W-tile + (128,128) out-tile ≈ 576 KiB ≪ 16 MiB VMEM, leaving
room for double buffering), feeding the MXU with the matmul and fusing the
bias + activation epilogue on the VPU instead of a second HBM round-trip.

``interpret=True`` keeps the lowered HLO executable on CPU PJRT.

Autodiff: ``pallas_call`` has no AD rule, so the kernel carries a
``custom_jvp`` whose tangent is expressed in plain jnp — it is linear in the
tangents, so XLA transposes it automatically for reverse mode. The *primal*
(the runtime hot path in ``f_eval``) always goes through the Pallas kernel;
the tangent/cotangent matmuls in the ``f_vjp``/``f_jvp`` artifacts are
ordinary XLA fusions.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One (bm, bn) output tile: full-K matmul + fused epilogue."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if activation == "tanh":
        acc = jnp.tanh(acc)
    elif activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    o_ref[...] = acc.astype(o_ref.dtype)


def _block(dim: int, target: int) -> int:
    """Largest divisor of `dim` not exceeding `target` (keeps the grid exact
    without padding logic — model dims are chosen MXU-friendly)."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


def _pallas_forward(x, w, b, activation: str, bm: int, bn: int):
    bsz, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), b.shape
    bm = _block(bsz, bm)
    bn = _block(n, bn)
    grid = (bsz // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=True,  # CPU-PJRT execution; TPU would emit Mosaic.
    )(x, w, b)


@functools.partial(jax.custom_jvp, nondiff_argnums=(3, 4, 5))
def _fused_linear(x, w, b, activation: str, bm: int, bn: int):
    return _pallas_forward(x, w, b, activation, bm, bn)


@_fused_linear.defjvp
def _fused_linear_jvp(activation, bm, bn, primals, tangents):
    x, w, b = primals
    dx, dw, db = tangents
    out = _pallas_forward(x, w, b, activation, bm, bn)
    # d(act(pre)) = act'(out) * dpre — act' recoverable from the output.
    dpre = dx @ w + x @ dw + db[None, :]
    if activation == "tanh":
        dout = (1.0 - out * out) * dpre
    elif activation == "relu":
        dout = jnp.where(out > 0.0, dpre, 0.0)
    else:
        dout = dpre
    return out, dout


def fused_linear(x, w, b, activation: str = "none", bm: int = 128, bn: int = 128):
    """``act(x @ w + b)`` with a tiled Pallas kernel (differentiable).

    Args:
      x: ``[B, K]`` input activations.
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
      activation: ``"none" | "tanh" | "relu"`` fused epilogue.
      bm, bn: target output tile sizes (clamped to divisors of B / N).

    Returns:
      ``[B, N]`` float32.
    """
    return _fused_linear(x, w, b, activation, bm, bn)
