"""Pairwise-interaction feature kernel for the three-body NODE (paper Eq. 33).

For planet positions ``r[B, 9]`` (three bodies × xyz) the augmented input
is, for every ordered pair ``i ≠ j``:

    d_ij = r_i − r_j,   d_ij/|d_ij|,   d_ij/|d_ij|²,   d_ij/|d_ij|³

concatenated with the raw positions: ``9 + 6×12 = 81`` features. This is the
NODE model's "partial physical knowledge": the network sees the
inverse-power pairwise geometry Newtonian gravity is built from, but not the
law itself.

One Pallas program per batch tile; pure VPU work (no MXU), fused into a
single VMEM-resident kernel instead of a dozen jnp ops with HBM round-trips.
Autodiff via ``custom_jvp`` whose tangent differentiates the jnp reference.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: ordered pairs (i, j), i != j, in row-major order.
PAIRS = [(i, j) for i in range(3) for j in range(3) if i != j]

#: number of output features: 9 raw coords + 12 per ordered pair.
AUG_FEATURES = 9 + len(PAIRS) * 12

#: softening epsilon for the inverse norms (matches the Rust simulator).
EPS = 1e-3


def aug_jnp(r):
    """Pure-jnp implementation — the oracle (ref.py) and the AD tangent."""
    feats = [r]
    for (i, j) in PAIRS:
        d = r[:, 3 * i : 3 * i + 3] - r[:, 3 * j : 3 * j + 3]
        n2 = jnp.sum(d * d, axis=-1, keepdims=True) + EPS * EPS
        n1 = jnp.sqrt(n2)
        feats += [d, d / n1, d / n2, d / (n2 * n1)]
    return jnp.concatenate(feats, axis=-1).astype(jnp.float32)


def _kernel(r_ref, o_ref):
    o_ref[...] = aug_jnp(r_ref[...])


def _pallas_forward(r, bm: int):
    bsz, nine = r.shape
    assert nine == 9, r.shape
    while bsz % bm:
        bm -= 1
    return pl.pallas_call(
        _kernel,
        grid=(bsz // bm,),
        in_specs=[pl.BlockSpec((bm, 9), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, AUG_FEATURES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, AUG_FEATURES), jnp.float32),
        interpret=True,
    )(r)


@functools.partial(jax.custom_jvp, nondiff_argnums=(1,))
def _pairwise_aug(r, bm: int):
    return _pallas_forward(r, bm)


@_pairwise_aug.defjvp
def _pairwise_aug_jvp(bm, primals, tangents):
    (r,) = primals
    (dr,) = tangents
    out = _pallas_forward(r, bm)
    _, dout = jax.jvp(aug_jnp, (r,), (dr,))
    return out, dout


def pairwise_aug(r, bm: int = 8):
    """Augmented pairwise features (paper Eq. 33).

    Args:
      r: ``[B, 9]`` flattened positions of the three bodies.
      bm: batch tile size target.

    Returns:
      ``[B, 81]`` float32 features (differentiable).
    """
    return _pairwise_aug(r, bm)
