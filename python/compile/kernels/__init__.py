"""Layer-1 Pallas kernels for the ACA Neural-ODE stack.

Kernels are authored for TPU-style tiling (VMEM blocks, MXU matmuls) but
lowered with ``interpret=True`` so the resulting HLO runs on the CPU PJRT
client — real-TPU lowering would emit Mosaic custom-calls the CPU plugin
cannot execute (see DESIGN.md §Hardware-Adaptation).
"""

from .fused_linear import fused_linear
from .pairwise_aug import pairwise_aug, AUG_FEATURES

__all__ = ["fused_linear", "pairwise_aug", "AUG_FEATURES"]
