"""Layer-2 JAX model zoo for the ACA reproduction.

Every model exposes a uniform artifact contract over a single flat parameter
vector ``theta[P]`` (DESIGN.md §5):

    init_params(seed[1] i32)                  -> theta[P]
    encode(theta, x[B,Din])                   -> z0[B,D]          (optional)
    encode_vjp(theta, x, w[B,D])              -> dtheta[P]
    f_eval(theta, t[1], z[B,D])               -> dz[B,D]
    f_vjp(theta, t, z, w[B,D])                -> (wJz[B,D], wJth[P])
    f_jvp(theta, t, z, v[B,D])                -> Jv[B,D]
    decode_loss(theta, zT[B,D], y[...])       -> (loss[1], pred[B,Dout])
    decode_loss_vjp(theta, zT, y)             -> (dzT[B,D], dtheta[P], loss[1])

Recurrent baselines (LSTM / GRU / RNN) instead export whole-graph
``loss_grad`` and ``predict`` / ``rollout`` artifacts.

The dynamics `f` are autonomous (paper Eq. 31) but take `t` for signature
uniformity. MLP layers go through the L1 Pallas kernel
(:func:`compile.kernels.fused_linear`); the three-body augmented features
through :func:`compile.kernels.pairwise_aug`.
"""

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import AUG_FEATURES, fused_linear, pairwise_aug

# --------------------------------------------------------------------------
# Flat-parameter plumbing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter tensor inside the flat theta vector."""

    name: str
    shape: Tuple[int, ...]
    #: init std; biases use 0.0, weights 1/sqrt(fan_in) by default.
    scale: Optional[float] = None

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def default_scale(self) -> float:
        if self.scale is not None:
            return self.scale
        if len(self.shape) <= 1:
            return 0.0  # bias
        fan_in = int(np.prod(self.shape[:-1]))
        return float(1.0 / np.sqrt(max(fan_in, 1)))


def n_params(specs: List[ParamSpec]) -> int:
    return sum(s.size for s in specs)


def unpack(theta, specs: List[ParamSpec]) -> Dict[str, jnp.ndarray]:
    """Slice the flat vector into named tensors."""
    out, off = {}, 0
    for s in specs:
        out[s.name] = theta[off : off + s.size].reshape(s.shape)
        off += s.size
    return out


def make_init(specs: List[ParamSpec]) -> Callable:
    """Build ``init_params(seed[1] i32) -> theta[P]`` (pure HLO via threefry)."""

    def init(seed):
        key = jax.random.PRNGKey(seed[0].astype(jnp.uint32))
        parts = []
        for s in specs:
            key, sub = jax.random.split(key)
            sc = s.default_scale()
            if sc == 0.0:
                parts.append(jnp.zeros((s.size,), jnp.float32))
            else:
                parts.append(sc * jax.random.normal(sub, (s.size,), jnp.float32))
        return jnp.concatenate(parts)

    return init


# --------------------------------------------------------------------------
# NODE model definition
# --------------------------------------------------------------------------


@dataclasses.dataclass
class NodeModel:
    """A Neural-ODE model: encoder -> ODE block -> loss head."""

    name: str
    specs: List[ParamSpec]
    batch: int
    dim_in: int
    dim_state: int
    dim_out: int
    #: "xent" (y: int32[B]) or "mse" (y: f32[B, dim_out]).
    loss: str
    f: Callable  # (params_dict, z[B,D]) -> dz[B,D]
    encode: Optional[Callable]  # (params_dict, x[B,Din]) -> z0[B,D]
    head: Callable  # (params_dict, z[B,D]) -> pred[B,Dout]

    @property
    def n_params(self) -> int:
        return n_params(self.specs)

    # ---- artifact functions (flat-theta signatures) ----

    def init_params_fn(self):
        return make_init(self.specs)

    def f_eval_fn(self):
        def f_eval(theta, t, z):
            del t  # autonomous
            return self.f(unpack(theta, self.specs), z)

        return f_eval

    def f_vjp_fn(self):
        f_eval = self.f_eval_fn()

        def f_vjp(theta, t, z, w):
            _, pull = jax.vjp(lambda th, zz: f_eval(th, t, zz), theta, z)
            dth, dz = pull(w)
            return dz, dth

        return f_vjp

    def f_jvp_fn(self):
        f_eval = self.f_eval_fn()

        def f_jvp(theta, t, z, v):
            _, jv = jax.jvp(lambda zz: f_eval(theta, t, zz), (z,), (v,))
            return jv

        return f_jvp

    def encode_fn(self):
        if self.encode is None:
            return None
        enc_impl = self.encode

        def encode(theta, x):
            return enc_impl(unpack(theta, self.specs), x)

        return encode

    def encode_vjp_fn(self):
        enc = self.encode_fn()
        if enc is None:
            return None

        def encode_vjp(theta, x, w):
            _, pull = jax.vjp(lambda th: enc(th, x), theta)
            (dth,) = pull(w)
            return dth

        return encode_vjp

    def _loss(self, theta, z, y):
        pred = self.head(unpack(theta, self.specs), z)
        if self.loss == "xent":
            logp = jax.nn.log_softmax(pred, axis=-1)
            nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
            loss = jnp.mean(nll)
        elif self.loss == "mse":
            loss = jnp.mean((pred - y) ** 2)
        else:
            raise ValueError(self.loss)
        return loss.reshape((1,)), pred

    def decode_loss_fn(self):
        def decode_loss(theta, z, y):
            return self._loss(theta, z, y)

        return decode_loss

    def decode_loss_vjp_fn(self):
        def decode_loss_vjp(theta, z, y):
            def scalar_loss(th, zz):
                return self._loss(th, zz, y)[0][0]

            loss, pull = jax.vjp(scalar_loss, theta, z)
            dth, dz = pull(jnp.float32(1.0))
            return dz, dth, loss.reshape((1,))

        return decode_loss_vjp

    def example_y(self):
        if self.loss == "xent":
            return jax.ShapeDtypeStruct((self.batch,), jnp.int32)
        return jax.ShapeDtypeStruct((self.batch, self.dim_out), jnp.float32)


# --------------------------------------------------------------------------
# Spiral classifier (quickstart / 2-D sanity task)
# --------------------------------------------------------------------------


def spiral_model(batch: int = 64) -> NodeModel:
    d, h = 16, 32
    specs = [
        ParamSpec("We", (2, d)),
        ParamSpec("be", (d,)),
        ParamSpec("W1", (d, h)),
        ParamSpec("b1", (h,)),
        ParamSpec("W2", (h, d), scale=0.1 / np.sqrt(h)),
        ParamSpec("b2", (d,)),
        ParamSpec("Wd", (d, 2)),
        ParamSpec("bd", (2,)),
    ]

    def f(p, z):
        u = fused_linear(z, p["W1"], p["b1"], "tanh")
        return fused_linear(u, p["W2"], p["b2"], "none")

    def encode(p, x):
        return fused_linear(x, p["We"], p["be"], "none")

    def head(p, z):
        return fused_linear(z, p["Wd"], p["bd"], "none")

    return NodeModel(
        name="spiral",
        specs=specs,
        batch=batch,
        dim_in=2,
        dim_state=d,
        dim_out=2,
        loss="xent",
        f=f,
        encode=encode,
        head=head,
    )


# --------------------------------------------------------------------------
# Image classifier (the CIFAR substitute; conv-NODE, paper Sec 4.2)
# --------------------------------------------------------------------------

IMG_SIDE = 16
IMG_CH = 8
IMG_SP = IMG_SIDE // 2  # encoder downsamples 2x


def _conv(x, w, stride: int = 1):
    """NCHW conv3x3, SAME padding."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def img_model(batch: int = 64, classes: int = 10) -> NodeModel:
    d = IMG_CH * IMG_SP * IMG_SP  # 8 * 8 * 8 = 512
    specs = [
        ParamSpec("Ke", (IMG_CH, 1, 3, 3), scale=1.0 / 3.0),
        ParamSpec("be", (IMG_CH,)),
        ParamSpec("K1", (IMG_CH, IMG_CH, 3, 3), scale=1.0 / (3.0 * np.sqrt(IMG_CH))),
        ParamSpec("b1", (IMG_CH,)),
        ParamSpec("K2", (IMG_CH, IMG_CH, 3, 3), scale=0.1 / (3.0 * np.sqrt(IMG_CH))),
        ParamSpec("b2", (IMG_CH,)),
        ParamSpec("Wd", (IMG_CH, classes)),
        ParamSpec("bd", (classes,)),
    ]

    def to_img(z):
        return z.reshape(-1, IMG_CH, IMG_SP, IMG_SP)

    def f(p, z):
        u = to_img(z)
        u = jnp.tanh(_conv(u, p["K1"]) + p["b1"][None, :, None, None])
        u = _conv(u, p["K2"]) + p["b2"][None, :, None, None]
        return u.reshape(z.shape)

    def encode(p, x):
        img = x.reshape(-1, 1, IMG_SIDE, IMG_SIDE)
        u = _conv(img, p["Ke"], stride=2) + p["be"][None, :, None, None]
        u = jnp.maximum(u, 0.0)
        return u.reshape(x.shape[0], -1)

    def head(p, z):
        # Global average pool over space, then the L1 kernel for the head.
        u = to_img(z).mean(axis=(2, 3))
        return fused_linear(u, p["Wd"], p["bd"], "none")

    return NodeModel(
        name="img",
        specs=specs,
        batch=batch,
        dim_in=IMG_SIDE * IMG_SIDE,
        dim_state=d,
        dim_out=classes,
        loss="xent",
        f=f,
        encode=encode,
        head=head,
    )


# --------------------------------------------------------------------------
# Time-series latent NODE (the Mujoco/Latent-ODE substitute, paper Sec 4.3)
# --------------------------------------------------------------------------

TS_OBS = 4
TS_ENC_WINDOW = 5  # first K observations feed the encoder


def ts_model(batch: int = 32) -> NodeModel:
    d, h = 8, 32
    din = TS_OBS * TS_ENC_WINDOW
    specs = [
        ParamSpec("We", (din, d)),
        ParamSpec("be", (d,)),
        ParamSpec("W1", (d, h)),
        ParamSpec("b1", (h,)),
        ParamSpec("W2", (h, d), scale=0.1 / np.sqrt(h)),
        ParamSpec("b2", (d,)),
        ParamSpec("Wd", (d, TS_OBS)),
        ParamSpec("bd", (TS_OBS,)),
    ]

    def f(p, z):
        u = fused_linear(z, p["W1"], p["b1"], "tanh")
        return fused_linear(u, p["W2"], p["b2"], "none")

    def encode(p, x):
        return fused_linear(x, p["We"], p["be"], "none")

    def head(p, z):
        return fused_linear(z, p["Wd"], p["bd"], "none")

    return NodeModel(
        name="ts",
        specs=specs,
        batch=batch,
        dim_in=din,
        dim_state=d,
        dim_out=TS_OBS,
        loss="mse",
        f=f,
        encode=encode,
        head=head,
    )


# --------------------------------------------------------------------------
# Three-body NODE — FC over augmented pairwise features (paper Eq. 33/34)
# --------------------------------------------------------------------------


def threebody_node_model(batch: int = 4) -> NodeModel:
    d = 18
    specs = [
        ParamSpec("Wa", (AUG_FEATURES, 9), scale=0.01),
        ParamSpec("ba", (9,)),
    ]

    def f(p, z):
        pos, vel = z[:, :9], z[:, 9:]
        aug = pairwise_aug(pos)
        acc = fused_linear(aug, p["Wa"], p["ba"], "none")
        return jnp.concatenate([vel, acc], axis=-1)

    def head(p, z):
        del p
        return z[:, :9]  # predicted positions

    return NodeModel(
        name="tb_node",
        specs=specs,
        batch=batch,
        dim_in=d,
        dim_state=d,
        dim_out=9,
        loss="mse",
        f=f,
        encode=None,
        head=head,
    )


# --------------------------------------------------------------------------
# Recurrent baselines: LSTM (three-body, Table 5), RNN/GRU (Table 4)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RecurrentModel:
    """Sequence-to-sequence baseline trained by whole-graph AOT autodiff.

    ``loss_grad(theta, x[B,T,Din], y[B,T,Dout]) -> (loss[1], dtheta[P])``
    ``predict(theta, x)                          -> pred[B,T,Dout]``
    ``rollout(theta, x0[B,Din])                  -> traj[B,steps,Dout]``
    """

    name: str
    specs: List[ParamSpec]
    batch: int
    seq_len: int
    dim_in: int
    dim_out: int
    cell: str  # "lstm" | "gru" | "rnn"
    hidden: int
    #: optional per-step input transform (e.g. pairwise_aug)
    in_transform: Optional[Callable] = None
    #: rollout feeds predictions back as inputs (requires dim_out == dim_in)
    rollout_steps: int = 0

    @property
    def n_params(self) -> int:
        return n_params(self.specs)

    def init_params_fn(self):
        return make_init(self.specs)

    def _step(self, p, carry, x_t):
        h, c = carry
        if self.cell == "lstm":
            gates = x_t @ p["Wx"] + h @ p["Wh"] + p["b"]
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
        elif self.cell == "gru":
            zu = jax.nn.sigmoid(x_t @ p["Wxz"] + h @ p["Whz"] + p["bz"])
            r = jax.nn.sigmoid(x_t @ p["Wxr"] + h @ p["Whr"] + p["br"])
            n = jnp.tanh(x_t @ p["Wxn"] + (r * h) @ p["Whn"] + p["bn"])
            h = (1.0 - zu) * n + zu * h
        elif self.cell == "rnn":
            h = jnp.tanh(x_t @ p["Wx"] + h @ p["Wh"] + p["b"])
        else:
            raise ValueError(self.cell)
        return (h, c)

    def _apply(self, p, x):
        """x: [B, T, Din] -> preds [B, T, Dout] (one-step-ahead)."""
        bsz = x.shape[0]
        h0 = jnp.zeros((bsz, self.hidden), jnp.float32)
        carry0 = (h0, h0)

        def scan_step(carry, x_t):
            if self.in_transform is not None:
                x_t = self.in_transform(x_t)
            carry = self._step(p, carry, x_t)
            out = carry[0] @ p["Wo"] + p["bo"]
            return carry, out

        _, outs = jax.lax.scan(scan_step, carry0, jnp.swapaxes(x, 0, 1))
        return jnp.swapaxes(outs, 0, 1)

    def predict_fn(self):
        def predict(theta, x):
            return self._apply(unpack(theta, self.specs), x)

        return predict

    def loss_grad_fn(self):
        def loss(theta, x, y):
            pred = self._apply(unpack(theta, self.specs), x)
            return jnp.mean((pred - y) ** 2)

        def loss_grad(theta, x, y):
            l, g = jax.value_and_grad(loss)(theta, x, y)
            return l.reshape((1,)), g

        return loss_grad

    def rollout_fn(self):
        """Autoregressive rollout: feed each prediction back as input."""
        if self.rollout_steps <= 0:
            return None

        def rollout(theta, x0):
            p = unpack(theta, self.specs)
            bsz = x0.shape[0]
            h0 = jnp.zeros((bsz, self.hidden), jnp.float32)

            def scan_step(carry, _):
                (h, c), x = carry
                x_in = self.in_transform(x) if self.in_transform is not None else x
                hc = self._step(p, (h, c), x_in)
                out = hc[0] @ p["Wo"] + p["bo"]
                return (hc, out), out

            (_, _), outs = jax.lax.scan(
                scan_step, ((h0, h0), x0), None, length=self.rollout_steps
            )
            return jnp.swapaxes(outs, 0, 1)

        return rollout

    def example_x(self):
        return jax.ShapeDtypeStruct((self.batch, self.seq_len, self.dim_in), jnp.float32)

    def example_y(self):
        return jax.ShapeDtypeStruct((self.batch, self.seq_len, self.dim_out), jnp.float32)


def _rec_specs(cell: str, din_t: int, hidden: int, dout: int) -> List[ParamSpec]:
    if cell == "lstm":
        core = [
            ParamSpec("Wx", (din_t, 4 * hidden)),
            ParamSpec("Wh", (hidden, 4 * hidden)),
            ParamSpec("b", (4 * hidden,)),
        ]
    elif cell == "gru":
        core = []
        for g in ("z", "r", "n"):
            core += [
                ParamSpec(f"Wx{g}", (din_t, hidden)),
                ParamSpec(f"Wh{g}", (hidden, hidden)),
                ParamSpec(f"b{g}", (hidden,)),
            ]
    elif cell == "rnn":
        core = [
            ParamSpec("Wx", (din_t, hidden)),
            ParamSpec("Wh", (hidden, hidden)),
            ParamSpec("b", (hidden,)),
        ]
    else:
        raise ValueError(cell)
    return core + [ParamSpec("Wo", (hidden, dout), scale=0.01), ParamSpec("bo", (dout,))]


def lstm_tb_model(batch: int = 4, seq_len: int = 50, aug: bool = False) -> RecurrentModel:
    """LSTM / LSTM-aug-input three-body baselines (paper Table 5)."""
    din_t = AUG_FEATURES if aug else 9
    hidden = 64
    return RecurrentModel(
        name="tb_lstm_aug" if aug else "tb_lstm",
        specs=_rec_specs("lstm", din_t, hidden, 9),
        batch=batch,
        seq_len=seq_len,
        dim_in=9,
        dim_out=9,
        cell="lstm",
        hidden=hidden,
        in_transform=pairwise_aug if aug else None,
        rollout_steps=200,
    )


def rnn_ts_model(cell: str = "gru", batch: int = 32, seq_len: int = 40) -> RecurrentModel:
    """RNN / RNN-GRU time-series baselines (paper Table 4). Input per step is
    the observed value concat Δt since the previous observation."""
    hidden = 32
    return RecurrentModel(
        name=f"ts_{cell}",
        specs=_rec_specs(cell, TS_OBS + 1, hidden, TS_OBS),
        batch=batch,
        seq_len=seq_len,
        dim_in=TS_OBS + 1,
        dim_out=TS_OBS,
        cell=cell,
        hidden=hidden,
    )


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------


def node_models() -> List[NodeModel]:
    return [spiral_model(), img_model(), ts_model(), threebody_node_model()]


def recurrent_models() -> List[RecurrentModel]:
    return [
        lstm_tb_model(aug=False),
        lstm_tb_model(aug=True),
        rnn_ts_model("rnn"),
        rnn_ts_model("gru"),
    ]
