"""Build-time compile package: L2 JAX models + L1 Pallas kernels + AOT lowering.

Never imported at runtime — `make artifacts` runs it once, the Rust
coordinator consumes only the emitted HLO text + manifests.
"""
