"""L2 correctness: model artifact functions vs jax autodiff ground truth."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M


NODE_MODELS = {m.name: m for m in M.node_models()}
REC_MODELS = {m.name: m for m in M.recurrent_models()}


def _theta(m, seed=0):
    return np.asarray(m.init_params_fn()(jnp.array([seed], jnp.int32)))


def _rand(rng, *shape, scale=0.5):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@pytest.mark.parametrize("name", list(NODE_MODELS))
def test_init_params_shape_and_determinism(name):
    m = NODE_MODELS[name]
    a = _theta(m, 1)
    b = _theta(m, 1)
    c = _theta(m, 2)
    assert a.shape == (m.n_params,)
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0, "different seeds must differ"
    assert np.isfinite(a).all()


@pytest.mark.parametrize("name", list(NODE_MODELS))
def test_f_eval_shape_finite(name):
    m = NODE_MODELS[name]
    rng = np.random.default_rng(0)
    z = _rand(rng, m.batch, m.dim_state)
    dz = np.asarray(m.f_eval_fn()(_theta(m), jnp.zeros(1), z))
    assert dz.shape == (m.batch, m.dim_state)
    assert np.isfinite(dz).all()


@pytest.mark.parametrize("name", list(NODE_MODELS))
def test_f_vjp_matches_jax_vjp(name):
    m = NODE_MODELS[name]
    rng = np.random.default_rng(1)
    theta = _theta(m)
    z = _rand(rng, m.batch, m.dim_state)
    w = _rand(rng, m.batch, m.dim_state)
    f_eval = m.f_eval_fn()
    wjz, wjp = m.f_vjp_fn()(theta, jnp.zeros(1), z, w)
    # ground truth through plain jax.vjp on the same function
    _, pull = jax.vjp(lambda th, zz: f_eval(th, jnp.zeros(1), zz), theta, z)
    dth, dz = pull(jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(wjz), np.asarray(dz), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(wjp), np.asarray(dth), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", list(NODE_MODELS))
def test_f_vjp_vs_finite_difference(name):
    """Independent check: directional derivative of <w, f(z)> via FD."""
    m = NODE_MODELS[name]
    rng = np.random.default_rng(2)
    theta = _theta(m)
    z = _rand(rng, m.batch, m.dim_state)
    w = _rand(rng, m.batch, m.dim_state)
    v = _rand(rng, m.batch, m.dim_state)
    f_eval = m.f_eval_fn()
    wjz, _ = m.f_vjp_fn()(theta, jnp.zeros(1), z, w)
    eps = 1e-3
    fp = np.asarray(f_eval(theta, jnp.zeros(1), z + eps * v))
    fm = np.asarray(f_eval(theta, jnp.zeros(1), z - eps * v))
    fd = float(np.sum(w * (fp - fm) / (2 * eps)))
    got = float(np.sum(np.asarray(wjz) * v))
    assert abs(got - fd) < 5e-2 * max(abs(fd), 1.0), (got, fd)


@pytest.mark.parametrize("name", list(NODE_MODELS))
def test_f_jvp_adjoint_identity(name):
    """<w, J v> == <w J, v>."""
    m = NODE_MODELS[name]
    rng = np.random.default_rng(3)
    theta = _theta(m)
    z = _rand(rng, m.batch, m.dim_state)
    w = _rand(rng, m.batch, m.dim_state)
    v = _rand(rng, m.batch, m.dim_state)
    jv = np.asarray(m.f_jvp_fn()(theta, jnp.zeros(1), z, v))
    wj, _ = m.f_vjp_fn()(theta, jnp.zeros(1), z, w)
    lhs = float(np.sum(w * jv))
    rhs = float(np.sum(np.asarray(wj) * v))
    assert abs(lhs - rhs) < 1e-3 * max(abs(lhs), 1.0), (lhs, rhs)


@pytest.mark.parametrize("name", list(NODE_MODELS))
def test_decode_loss_and_vjp_consistent(name):
    m = NODE_MODELS[name]
    rng = np.random.default_rng(4)
    theta = _theta(m)
    z = _rand(rng, m.batch, m.dim_state)
    if m.loss == "xent":
        y = rng.integers(0, m.dim_out, size=(m.batch,)).astype(np.int32)
    else:
        y = _rand(rng, m.batch, m.dim_out)
    loss, pred = m.decode_loss_fn()(theta, z, y)
    dz, dth, loss2 = m.decode_loss_vjp_fn()(theta, z, y)
    assert pred.shape == (m.batch, m.dim_out)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(loss2), rtol=1e-6)
    assert np.isfinite(np.asarray(dz)).all()
    assert np.isfinite(np.asarray(dth)).all()
    # FD check on the z gradient along a random direction.
    v = _rand(rng, m.batch, m.dim_state, scale=1.0)
    eps = 1e-3
    lp = float(np.asarray(m.decode_loss_fn()(theta, z + eps * v, y)[0])[0])
    lm = float(np.asarray(m.decode_loss_fn()(theta, z - eps * v, y)[0])[0])
    fd = (lp - lm) / (2 * eps)
    got = float(np.sum(np.asarray(dz) * v))
    assert abs(got - fd) < 5e-2 * max(abs(fd), 1e-3), (got, fd)


@pytest.mark.parametrize("name", [n for n, m in NODE_MODELS.items() if m.encode is not None])
def test_encode_and_vjp(name):
    m = NODE_MODELS[name]
    rng = np.random.default_rng(5)
    theta = _theta(m)
    x = _rand(rng, m.batch, m.dim_in)
    z0 = np.asarray(m.encode_fn()(theta, x))
    assert z0.shape == (m.batch, m.dim_state)
    w = _rand(rng, m.batch, m.dim_state)
    dth = np.asarray(m.encode_vjp_fn()(theta, x, w))
    assert dth.shape == (m.n_params,)
    # ground truth
    _, pull = jax.vjp(lambda th: m.encode_fn()(th, x), theta)
    (want,) = pull(jnp.asarray(w))
    np.testing.assert_allclose(dth, np.asarray(want), rtol=1e-5, atol=1e-6)


def test_xent_loss_value():
    """Cross-entropy of uniform logits is log(C)."""
    m = NODE_MODELS["spiral"]
    theta = np.zeros(m.n_params, np.float32)  # zero head -> uniform logits
    z = np.random.default_rng(0).standard_normal((m.batch, m.dim_state)).astype(np.float32)
    y = np.zeros((m.batch,), np.int32)
    loss, _ = m.decode_loss_fn()(theta, z, y)
    np.testing.assert_allclose(np.asarray(loss)[0], np.log(2.0), rtol=1e-5)


def test_tb_node_velocity_passthrough():
    """d(pos)/dt must be exactly the velocity block (paper Eq. 34 structure)."""
    m = NODE_MODELS["tb_node"]
    rng = np.random.default_rng(6)
    theta = _theta(m)
    z = _rand(rng, m.batch, 18, scale=1.0)
    dz = np.asarray(m.f_eval_fn()(theta, jnp.zeros(1), z))
    np.testing.assert_allclose(dz[:, :9], z[:, 9:], rtol=1e-6)


# ---------------------------------------------------------------------------
# Recurrent baselines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(REC_MODELS))
def test_recurrent_shapes_and_loss_grad(name):
    m = REC_MODELS[name]
    rng = np.random.default_rng(7)
    theta = np.asarray(m.init_params_fn()(jnp.array([0], jnp.int32)))
    assert theta.shape == (m.n_params,)
    x = _rand(rng, m.batch, m.seq_len, m.dim_in)
    y = _rand(rng, m.batch, m.seq_len, m.dim_out)
    pred = np.asarray(m.predict_fn()(theta, x))
    assert pred.shape == (m.batch, m.seq_len, m.dim_out)
    loss, grad = m.loss_grad_fn()(theta, x, y)
    assert np.isfinite(np.asarray(loss)).all()
    assert np.asarray(grad).shape == (m.n_params,)
    assert np.isfinite(np.asarray(grad)).all()
    # Gradient direction actually decreases the loss.
    theta2 = theta - 0.5 * np.asarray(grad)
    loss2, _ = m.loss_grad_fn()(theta2, x, y)
    assert float(np.asarray(loss2)[0]) < float(np.asarray(loss)[0])


@pytest.mark.parametrize("name", ["tb_lstm", "tb_lstm_aug"])
def test_rollout_shape(name):
    m = REC_MODELS[name]
    rng = np.random.default_rng(8)
    theta = np.asarray(m.init_params_fn()(jnp.array([0], jnp.int32)))
    x0 = _rand(rng, m.batch, m.dim_in)
    traj = np.asarray(m.rollout_fn()(theta, x0))
    assert traj.shape == (m.batch, m.rollout_steps, m.dim_out)
    assert np.isfinite(traj).all()


def test_loss_grad_matches_fd():
    m = REC_MODELS["ts_rnn"]
    rng = np.random.default_rng(9)
    theta = np.asarray(m.init_params_fn()(jnp.array([3], jnp.int32)))
    x = _rand(rng, m.batch, m.seq_len, m.dim_in)
    y = _rand(rng, m.batch, m.seq_len, m.dim_out)
    loss, grad = m.loss_grad_fn()(theta, x, y)
    v = rng.standard_normal(m.n_params).astype(np.float32) * 0.1
    eps = 1e-2
    lp, _ = m.loss_grad_fn()(theta + eps * v, x, y)
    lm, _ = m.loss_grad_fn()(theta - eps * v, x, y)
    fd = (float(np.asarray(lp)[0]) - float(np.asarray(lm)[0])) / (2 * eps)
    got = float(np.sum(np.asarray(grad) * v))
    assert abs(got - fd) < 0.1 * max(abs(fd), 1e-4), (got, fd)
