"""AOT pipeline: HLO text round-trips through the XLA client and the
manifests describe the artifacts faithfully."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M


def test_hlo_text_parses_back():
    """Lower a model's f_eval to HLO text and parse it back through the XLA
    text parser — the same parser the Rust loader uses
    (HloModuleProto::from_text_file). Numerical execution equivalence of the
    text path is covered by the Rust integration test
    rust/tests/runtime_round_trip.rs, since this jaxlib's Python client only
    compiles StableHLO, not HLO protos.
    """
    m = M.spiral_model(batch=8)
    theta_spec = jax.ShapeDtypeStruct((m.n_params,), jnp.float32)
    lowered = jax.jit(m.f_eval_fn()).lower(
        theta_spec,
        jax.ShapeDtypeStruct((1,), jnp.float32),
        jax.ShapeDtypeStruct((8, m.dim_state), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    module = xc._xla.hlo_module_from_text(text)
    rt = module.to_string()
    # Parameters survive the round trip with their shapes.
    assert f"f32[{m.n_params}]" in rt
    assert f"f32[8,{m.dim_state}]" in rt


@pytest.mark.slow
def test_full_export_manifests(tmp_path):
    """Export two representative models and validate manifest contents."""
    aot_dir = str(tmp_path)
    M_node = M.spiral_model()
    aot.export_node_model(M_node, aot_dir)
    man = json.load(open(os.path.join(aot_dir, "spiral", "manifest.json")))
    assert man["kind"] == "node"
    assert man["n_params"] == M_node.n_params
    assert man["has_encoder"]
    for name, art in man["artifacts"].items():
        path = os.path.join(aot_dir, "spiral", art["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, name
    # shape sanity: f_eval inputs are [P], [1], [B,D]
    fi = man["artifacts"]["f_eval"]["inputs"]
    assert fi[0]["shape"] == [M_node.n_params]
    assert fi[1]["shape"] == [1]
    assert fi[2]["shape"] == [man["batch"], man["dim_state"]]

    M_rec = M.rnn_ts_model("rnn")
    aot.export_recurrent_model(M_rec, aot_dir)
    man_r = json.load(open(os.path.join(aot_dir, "ts_rnn", "manifest.json")))
    assert man_r["kind"] == "recurrent"
    assert set(man_r["artifacts"]) >= {"init_params", "loss_grad", "predict"}


def test_dtype_tags():
    assert aot._dtype_tag(jnp.float32) == "f32"
    assert aot._dtype_tag(jnp.int32) == "i32"


def test_cli_filter(tmp_path):
    """--model filter exports only the named model."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--model", "spiral"],
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        check=True,
    )
    assert "spiral" in out.stdout
    assert os.path.exists(tmp_path / "spiral" / "manifest.json")
    assert not os.path.exists(tmp_path / "img")
