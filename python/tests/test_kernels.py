"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; assert_allclose against ref.py is the
core correctness signal of the build (tolerances are f32-scale).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear, pairwise_aug, AUG_FEATURES
from compile.kernels.ref import fused_linear_ref, pairwise_aug_ref

import jax
import jax.numpy as jnp


def _arr(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 33),
    k=st.integers(1, 48),
    n=st.integers(1, 40),
    act=st.sampled_from(["none", "tanh", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(b, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, bias = _arr(rng, b, k), _arr(rng, k, n), _arr(rng, n)
    got = np.asarray(fused_linear(x, w, bias, act))
    want = np.asarray(fused_linear_ref(x, w, bias, act))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([1, 2, 8, 128]),
    bn=st.sampled_from([1, 4, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_tile_size_invariance(bm, bn, seed):
    """The result must not depend on the tiling."""
    rng = np.random.default_rng(seed)
    x, w, bias = _arr(rng, 16, 12), _arr(rng, 12, 20), _arr(rng, 20)
    a = np.asarray(fused_linear(x, w, bias, "tanh", bm=bm, bn=bn))
    b = np.asarray(fused_linear_ref(x, w, bias, "tanh"))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_fused_linear_large_scale_values():
    """tanh saturation and big magnitudes stay exact."""
    rng = np.random.default_rng(0)
    x, w, bias = _arr(rng, 8, 8, scale=100.0), _arr(rng, 8, 8, scale=100.0), _arr(rng, 8)
    got = np.asarray(fused_linear(x, w, bias, "tanh"))
    want = np.asarray(fused_linear_ref(x, w, bias, "tanh"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("act", ["none", "tanh", "relu"])
def test_fused_linear_grad_matches_ref_grad(act):
    """custom_jvp tangent: reverse-mode grads equal the jnp reference grads."""
    rng = np.random.default_rng(7)
    x, w, bias = _arr(rng, 6, 5), _arr(rng, 5, 4), _arr(rng, 4)

    def loss_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(fused_linear_ref(x, w, b, act) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, bias)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_fused_linear_jvp_matches_ref_jvp():
    rng = np.random.default_rng(3)
    x, w, bias = _arr(rng, 4, 6), _arr(rng, 6, 3), _arr(rng, 3)
    dx, dw, db = _arr(rng, 4, 6), _arr(rng, 6, 3), _arr(rng, 3)
    _, jk = jax.jvp(lambda *a: fused_linear(*a, "tanh"), (x, w, bias), (dx, dw, db))
    _, jr = jax.jvp(lambda *a: fused_linear_ref(*a, "tanh"), (x, w, bias), (dx, dw, db))
    np.testing.assert_allclose(np.asarray(jk), np.asarray(jr), rtol=1e-4, atol=1e-5)


def test_fused_linear_rejects_bad_activation():
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError):
        fused_linear(_arr(rng, 2, 2), _arr(rng, 2, 2), _arr(rng, 2), "gelu")


# ---------------------------------------------------------------------------
# pairwise_aug
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 17),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_aug_matches_ref(b, scale, seed):
    rng = np.random.default_rng(seed)
    r = _arr(rng, b, 9, scale=scale)
    got = np.asarray(pairwise_aug(r))
    want = np.asarray(pairwise_aug_ref(r))
    assert got.shape == (b, AUG_FEATURES)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_pairwise_aug_near_collision_softened():
    """Coincident bodies must stay finite (softening)."""
    r = np.zeros((2, 9), np.float32)
    out = np.asarray(pairwise_aug(r))
    assert np.isfinite(out).all()


def test_pairwise_aug_translation_invariant_differences():
    """All pairwise-difference features are translation invariant; only the
    raw-coordinate block (first 9) shifts."""
    rng = np.random.default_rng(5)
    r = _arr(rng, 3, 9)
    shift = np.tile(np.array([1.0, -2.0, 0.5], np.float32), 3)
    a = np.asarray(pairwise_aug(r))
    b = np.asarray(pairwise_aug(r + shift[None, :]))
    np.testing.assert_allclose(a[:, 9:], b[:, 9:], rtol=1e-4, atol=1e-5)
    assert np.abs(a[:, :9] - b[:, :9]).max() > 0.4


def test_pairwise_aug_grad_matches_ref():
    rng = np.random.default_rng(11)
    r = _arr(rng, 4, 9)
    gk = jax.grad(lambda r: jnp.sum(pairwise_aug(r) ** 2))(r)
    gr = jax.grad(lambda r: jnp.sum(pairwise_aug_ref(r) ** 2))(r)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-4, atol=1e-4)


def test_pairwise_aug_feature_layout():
    """First 9 features are the raw positions; next 3 are r_0 − r_1."""
    r = np.arange(9, dtype=np.float32)[None, :]
    out = np.asarray(pairwise_aug(r))
    np.testing.assert_allclose(out[0, :9], r[0])
    np.testing.assert_allclose(out[0, 9:12], r[0, 0:3] - r[0, 3:6], rtol=1e-6)
